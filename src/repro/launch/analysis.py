"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

``cost_analysis()`` reports per-device FLOPs/bytes for the SPMD program;
collective bytes are parsed from the compiled HLO text by summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (also per-device).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,1728]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals from (per-device) HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        if "-done(" in line:      # async pairs: count the start only
            continue
        out[kind] += _shape_bytes(dtype, dims)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0          # 6*N*D useful flops (global)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        """Upper bound: HLO 'bytes accessed' counts every operand of every
        (CPU-lowered, largely unfused) op — each buffer is charged once per
        consumer.  A fused TPU pipeline moves far less HBM traffic."""
        return self.bytes_per_device / HBM_BW

    @property
    def t_memory_lower(self) -> float:
        """Lower bound: every resident byte of the step (arguments +
        outputs + peak temporaries, from memory_analysis) is written or
        read at least once."""
        return (self.argument_bytes + self.output_bytes +
                self.temp_bytes) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Pessimistic: useful compute time over the dominant term with
        the *unfused upper-bound* memory term."""
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / bound if bound else 0.0

    @property
    def roofline_fraction_fused(self) -> float:
        """Fused-pipeline estimate: memory term replaced by its lower
        bound (resident bytes).  The achievable fraction on TPU lies
        between `roofline_fraction` and this value, much nearer this one
        for fusion-friendly stacks."""
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        bound = max(self.t_compute, self.t_memory_lower, self.t_collective)
        return t_useful / bound if bound else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "t_memory_lower_s": self.t_memory_lower,
            "roofline_fraction_fused": self.roofline_fraction_fused,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
        }


def model_flops_estimate(cfg, shape_kind: str, seq: int, batch: int,
                         n_params_active: int, n_params_embed: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N per token (decode)."""
    n = n_params_active - n_params_embed
    tokens = seq * batch
    if shape_kind == "train":
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * batch            # decode: one token per sequence


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
    )
