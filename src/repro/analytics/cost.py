"""Cost-based pushdown optimizer — SAGE's 'decide where computation
runs' claim made concrete.

The paper's central argument is that percipient storage should *choose*
whether a computation moves to the data or the data moves to the
computation, per piece of data, from what the system knows about tiers
and workload.  PR 2's engine always pushed the pushable prefix down;
this module makes fragment placement a costed decision **per
partition**, from three inputs:

  * **tier parameters** — latency/bandwidth of the tier each partition
    lives on, from the HSM tier map (``core.hsm.tier_params``);
  * **percipience heat** — predicted storage-side contention
    (``PercipientPolicy.load_factor``): pushing compute at a partition
    whose storage node is busy serving demand reads is discounted;
  * **selectivity statistics** — per-partition row counts, per-column
    min/max, and KMV distinct-estimate sketches held by the
    ``StatsCatalog``, collected incrementally: ObjectStore write hooks
    invalidate, and shipped fragments piggyback a fresh summary on
    their partials (the store already has the bytes in hand, so stats
    are free), harvested through a FunctionShipper result observer.

Per partition the optimizer picks one of three modes:

  * ``ship``   — push the fused fragment to the store; only the
    (estimated-small) partial crosses back;
  * ``fetch``  — move the raw bytes and compute caller-side; wins when
    estimated selectivity ≈ 1 makes pushdown pointless (same bytes
    cross either way, and the caller's CPUs are faster/less contended);
  * ``cached`` — reuse a prior partial for the identical fragment over
    the identical object version (zero I/O; correct by construction
    since the cache key includes the version).

Cold start is safe by design: a partition with no statistics always
ships (the always-push behaviour PR 2 had), never crashes, and the
shipped fragment's piggybacked summary fills the catalog for next time.
Every decision is recorded in ADDB (op ``analytics_plan``) so chosen-
plan quality is auditable against the always-push / always-fetch
oracles (``bench_analytics``).
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.hsm import TierParams, tier_params

SHIP = "ship"
FETCH = "fetch"
CACHED = "cached"

STATS_KEY = "__sage_stats__"      # piggyback marker in shipped partials
DEFAULT_SEL = 0.5                 # selectivity of an inestimable predicate
KMV_K = 64                        # k-minimum-values sketch size
HIST_BINS = 16                    # equi-width per-column histogram bins


# ---------------------------------------------------------------------------
# partition statistics
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ColumnStats:
    lo: float
    hi: float
    distinct: float               # KMV estimate (exact when small)
    # equi-width counts over [lo, hi] — range-predicate selectivity
    # interpolates the real distribution instead of assuming uniform.
    # None on summaries from before histograms existed (still decodes).
    hist: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class PartitionStats:
    oid: str
    version: int
    rows: int
    ncols: int
    nbytes: int
    cols: List[ColumnStats]

    @property
    def itemsize(self) -> float:
        return self.nbytes / max(self.rows * self.ncols, 1)

    @staticmethod
    def from_summary(oid: str, version: int, d: Dict) -> "PartitionStats":
        return PartitionStats(
            oid, version, int(d["rows"]), int(d["ncols"]), int(d["nbytes"]),
            [ColumnStats(c["lo"], c["hi"], c["distinct"],
                         tuple(c["hist"]) if c.get("hist") else None)
             for c in d["cols"]])


def _kmv_distinct(v: np.ndarray, k: int = KMV_K) -> float:
    """Distinct-count estimate via a k-minimum-values sketch: hash every
    value to [0, 1), keep the k smallest; est = (k-1) / kth-smallest.
    Exact (modulo hash collisions) when there are fewer than k distinct
    hashes.  O(n) time, O(k) summary — the sketch the paper-scale stats
    substrate needs, since partitions can be arbitrarily wide."""
    x = np.ascontiguousarray(v)
    if x.size == 0:
        return 0.0
    if x.dtype.kind == "f":
        h = x.astype(np.float64).view(np.int64)
    else:
        h = x.astype(np.int64)
    # splitmix64-style mixing; numpy int64 arithmetic wraps, which is
    # exactly what the hash wants
    h = h * np.int64(-7046029254386353131)
    h = h ^ (h >> 33)
    h = h * np.int64(-4417276706812531889)
    h = h ^ (h >> 29)
    u = (h.astype(np.uint64) >> np.uint64(11)).astype(np.float64) / (1 << 53)
    u = np.unique(u)
    if u.size <= k:
        return float(u.size)
    kth = float(np.partition(u, k - 1)[k - 1])
    return (k - 1) / max(kth, 1e-12)


def summarize_rows(arr: np.ndarray) -> Dict:
    """JSON-able stats summary of one partition's row array — computed
    store-side (piggybacked on fragments) or caller-side (analyze)."""
    rows = np.asarray(arr)
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    elif rows.ndim > 2:
        rows = rows.reshape(rows.shape[0], -1)
    n, ncols = rows.shape
    cols = []
    for c in range(ncols):
        if n == 0:
            cols.append({"lo": 0.0, "hi": 0.0, "distinct": 0.0})
        else:
            v = rows[:, c]
            lo, hi = float(np.min(v)), float(np.max(v))
            col = {"lo": lo, "hi": hi, "distinct": _kmv_distinct(v)}
            if hi > lo:
                col["hist"] = np.histogram(
                    v.astype(np.float64), bins=HIST_BINS,
                    range=(lo, hi))[0].tolist()
            cols.append(col)
    return {"rows": int(n), "ncols": int(ncols),
            "nbytes": int(rows.nbytes), "cols": cols}


class StatsCatalog:
    """Per-partition selectivity statistics, collected incrementally.

    Freshness is version-based: stats carry the object version they were
    computed at; ``get`` returns None when the live version moved on.
    Three feeds keep the catalog current:

      * ``attach(store)`` — ObjectStore write hooks invalidate on every
        committed write/append, FDMI deletes drop entries;
      * ``attach_shipper(shipper)`` — a FunctionShipper observer
        harvests summaries piggybacked on shipped fragment results
        (``{STATS_KEY}: summary`` alongside the partial);
      * ``analyze(clovis, container)`` — eager scan (internal reads: no
        heat/access pollution) for benchmarks and warm starts.

    ``version`` is a monotonic change counter bumped on every observe /
    invalidate / feedback fold — anything caching decisions derived from
    the catalog (the serving plan cache) keys on it and re-derives when
    it moves.
    """

    def __init__(self, max_partitions: int = 8192,
                 max_sel_obs: int = 4096):
        self.max_partitions = max_partitions
        self.max_sel_obs = max_sel_obs
        self.version = 0              # bumped (under _lock) on any change
        # per-container change counters + a global component: anything
        # caching per-container derivations (the serving plan cache)
        # keys on container_version() so a write to one container never
        # invalidates another container's cached plans
        self._cver: Dict[str, int] = {}
        self._gver = 0                # cross-container feedback (node bw)
        self._stats: Dict[str, PartitionStats] = {}
        self._node_obs: Dict[str, Dict[str, float]] = {}
        # (frag_key, oid) -> EWMA of actually-observed selectivity
        self._sel_obs: Dict[Any, float] = {}
        self._store = None
        self._lock = threading.Lock()

    # -- feeds ---------------------------------------------------------

    def attach(self, store) -> "StatsCatalog":
        with self._lock:
            if store is self._store:
                return self
            self._store = store
        store.register_write_hook(self._on_write)
        store.fdmi_register(self._on_fdmi)
        return self

    def detach(self):
        """Unhook from the store (engines that default-created their
        catalog call this on close so short-lived engines don't leave
        hooks behind on a long-lived store)."""
        with self._lock:
            store, self._store = self._store, None
        if store is None:
            return
        store.unregister_write_hook(self._on_write)
        store.fdmi_unregister(self._on_fdmi)

    def attach_shipper(self, shipper) -> "StatsCatalog":
        shipper.add_observer(self._on_ship)
        return self

    def _on_write(self, oid: str, nbytes: int):
        self.invalidate(oid)

    def _container_of(self, oid: str) -> str:
        """The container an oid-scoped change belongs to — live store
        metadata when available (computed outside ``_lock``; store
        facades may take their own locks), oid prefix as the fallback
        (the repo-wide ``<container>/<name>`` naming), else a shared
        bucket."""
        with self._lock:
            store = self._store
        if store is not None:
            try:
                return store.meta(oid).container
            except KeyError:
                pass
        if "/" in oid:
            return oid.split("/", 1)[0]
        return "default"

    def _on_fdmi(self, event: str, oid: str, info: Dict):
        if event == "delete":
            self.invalidate(oid)
        elif event == "migrate":
            # migration moves bytes, not content: re-stamp the stored
            # version so stats survive HSM tier changes
            with self._lock:
                store = self._store
            if store is None:
                return
            try:
                meta = store.meta(oid)
                version, container = meta.version, meta.container
            except KeyError:
                return
            # re-read and replace in ONE critical section: a concurrent
            # invalidate-then-observe must not be clobbered by a stale
            # re-stamp (the entry is skipped if it already carries the
            # live version)
            with self._lock:
                st = self._stats.get(oid)
                if st is not None and st.version != version:
                    self._stats[oid] = PartitionStats(
                        st.oid, version, st.rows, st.ncols, st.nbytes,
                        st.cols)
                    self.version += 1
                    self._cver[container] = \
                        self._cver.get(container, 0) + 1

    def _on_ship(self, res):
        """FunctionShipper observer: harvest piggybacked summaries,
        stamped with the version the shipped read actually saw (not the
        live version, which a concurrent write may have moved past)."""
        if not res.ok or not isinstance(res.value, dict):
            return
        summary = res.value.get(STATS_KEY)
        if summary is None or res.version < 0:
            return
        self.observe(res.oid, res.version, summary)

    # -- catalog -------------------------------------------------------

    def observe(self, oid: str, version: int, summary: Dict):
        st = PartitionStats.from_summary(oid, version, summary)
        container = self._container_of(oid)
        with self._lock:
            if (len(self._stats) >= self.max_partitions
                    and oid not in self._stats):
                # drop an arbitrary entry: the catalog is a cache, and a
                # miss only costs one always-push partition
                self._stats.pop(next(iter(self._stats)))
            self._stats[oid] = st
            self.version += 1
            self._cver[container] = self._cver.get(container, 0) + 1

    def invalidate(self, oid: str):
        container = self._container_of(oid)
        with self._lock:
            dropped = self._stats.pop(oid, None) is not None
            stale = [k for k in self._sel_obs if k[1] == oid]
            for k in stale:
                del self._sel_obs[k]
            if dropped or stale:
                self.version += 1
                self._cver[container] = self._cver.get(container, 0) + 1

    # -- observed-selectivity feedback (estimate correction) -----------

    def observe_selectivity(self, frag_key: str, oid: str, actual: float,
                            alpha: float = 0.5):
        """Fold the selectivity a shipped fragment *actually* delivered
        (rows out / rows in) into an EWMA keyed by (fragment, object).
        The cost model prefers this over the uniform-range estimate for
        repeats of the same fragment — mis-estimates self-correct from
        real executions instead of compounding (ROADMAP's observed-
        feedback item, scoped to the per-fragment selectivity the
        ship-vs-fetch decision hinges on)."""
        actual = float(min(max(actual, 0.0), 1.0))
        key = (frag_key, oid)
        container = self._container_of(oid)
        with self._lock:
            prev = self._sel_obs.get(key)
            if prev is None:
                if len(self._sel_obs) >= self.max_sel_obs:
                    self._sel_obs.pop(next(iter(self._sel_obs)))
                self._sel_obs[key] = actual
                self.version += 1
                self._cver[container] = self._cver.get(container, 0) + 1
            else:
                self._sel_obs[key] = prev + alpha * (actual - prev)
                # re-observing a stable selectivity must not thrash
                # version-keyed plan caches: bump only on material drift
                if abs(self._sel_obs[key] - prev) > 0.02:
                    self.version += 1
                    self._cver[container] = \
                        self._cver.get(container, 0) + 1

    def observed_selectivity(self, frag_key: str, oid: str
                             ) -> Optional[float]:
        with self._lock:
            return self._sel_obs.get((frag_key, oid))

    def container_version(self, container: str) -> int:
        """Change counter scoped to one container (plus the global
        feedback component): bumps when *that* container's stats,
        selectivity feedback, or any node-bandwidth estimate move —
        and stays put when unrelated containers take writes.  The
        serving plan cache keys on this instead of ``version`` so
        sustained ingest into one container cannot evict every other
        container's warm plans."""
        with self._lock:
            return self._cver.get(container, 0) + self._gver

    def get(self, oid: str) -> Optional[PartitionStats]:
        """Fresh stats for ``oid`` or None (missing or stale)."""
        with self._lock:
            st = self._stats.get(oid)
        if st is None:
            return None
        if self._store is not None:
            try:
                if self._store.meta(oid).version != st.version:
                    return None
            except KeyError:
                return None
        return st

    def fresh(self, oid: str) -> bool:
        return self.get(oid) is not None

    def analyze(self, clovis, container: str) -> int:
        """Eagerly compute stats for every object in ``container`` via
        internal reads (no demand-access bookkeeping).  Returns the
        number of partitions summarized."""
        n = 0
        for oid in clovis.container(container):
            try:
                arr = clovis.materialize(oid, _notify=False)
                version = clovis.store.meta(oid).version
            except (KeyError, IOError):
                continue
            self.observe(oid, version, summarize_rows(arr))
            n += 1
        return n

    def __len__(self) -> int:
        with self._lock:
            return len(self._stats)

    # -- per-node fragment-latency feedback (cluster cost model) -------

    def observe_node_latency(self, node: str, nbytes: int, wall_s: float,
                             alpha: float = 0.25):
        """Fold one observed shipped-fragment execution into the node's
        effective-bandwidth estimate (EWMA of bytes scanned / wall
        seconds).  The cluster shipper reports every routed fragment
        here, so the cost model's per-node TierParams converge from the
        device model's nameplate numbers toward what each node actually
        delivers — a busy or degraded node gets discounted without any
        explicit signal (ROADMAP's observed-feedback item, scoped to
        the per-node timing the placement decision needs)."""
        bw = nbytes / max(wall_s, 1e-9)
        with self._lock:
            obs = self._node_obs.setdefault(
                node, {"read_bw": bw, "samples": 0.0, "bytes": 0.0,
                       "wall_s": 0.0})
            prev_bw = obs["read_bw"]
            obs["read_bw"] += alpha * (bw - obs["read_bw"])
            obs["samples"] += 1
            obs["bytes"] += nbytes
            obs["wall_s"] += wall_s
            # only a *material* bandwidth shift (>10%) invalidates
            # version-keyed plan caches — every ship nudges the EWMA,
            # and bumping per ship would make cached plans unhittable
            if abs(obs["read_bw"] - prev_bw) > 0.1 * max(prev_bw, 1e-9):
                self.version += 1
                # node bandwidth shifts re-cost every container's plans
                self._gver += 1

    def node_read_bw(self, node: str) -> Optional[float]:
        """Learned effective scan bandwidth of a node (bytes/s), or
        None before the first observation."""
        with self._lock:
            obs = self._node_obs.get(node)
            return obs["read_bw"] if obs else None

    def node_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-node observation summary: {node: {read_bw, samples,
        bytes, wall_s}} — bench_cluster reports it next to throughput."""
        with self._lock:
            return {n: dict(o) for n, o in self._node_obs.items()}


# ---------------------------------------------------------------------------
# selectivity estimation over fragment specs
# ---------------------------------------------------------------------------

def _hist_frac_below(cs: ColumnStats, v: float) -> Optional[float]:
    """Approximate fraction of rows with value < v from the equi-width
    histogram (linear interpolation inside v's bin), or None when the
    column carries no histogram."""
    if not cs.hist:
        return None
    total = float(sum(cs.hist))
    if total <= 0 or cs.hi <= cs.lo:
        return None
    if v <= cs.lo:
        return 0.0
    if v >= cs.hi:
        return 1.0
    width = (cs.hi - cs.lo) / len(cs.hist)
    pos = (v - cs.lo) / width
    b = min(int(pos), len(cs.hist) - 1)
    below = sum(cs.hist[:b]) + cs.hist[b] * (pos - b)
    return float(np.clip(below / total, 0.0, 1.0))


def _cmp_selectivity(op: str, cs: ColumnStats, v: float) -> float:
    """Selectivity of ``col <op> v`` — from the per-column equi-width
    histogram when the summary carries one (real distribution, so skew
    stops fooling the ship-vs-fetch decision), falling back to a
    uniform-range assumption; the distinct sketch handles equality."""
    span = cs.hi - cs.lo
    if op in (">", ">="):
        if span <= 0:
            return 1.0 if (cs.lo > v or (op == ">=" and cs.lo >= v)) else 0.0
        below = _hist_frac_below(cs, v)
        if below is not None:
            return 1.0 - below
        return float(np.clip((cs.hi - v) / span, 0.0, 1.0))
    if op in ("<", "<="):
        if span <= 0:
            return 1.0 if (cs.lo < v or (op == "<=" and cs.lo <= v)) else 0.0
        below = _hist_frac_below(cs, v)
        if below is not None:
            return below
        return float(np.clip((v - cs.lo) / span, 0.0, 1.0))
    if op == "==":
        if v < cs.lo or v > cs.hi:
            return 0.0
        return 1.0 / max(cs.distinct, 1.0)
    if op == "!=":
        if v < cs.lo or v > cs.hi:
            return 1.0
        return 1.0 - 1.0 / max(cs.distinct, 1.0)
    raise ValueError(op)

_FLIP = {">": "<", ">=": "<=", "<": ">", "<=": ">=", "==": "==", "!=": "!="}
_CMPS = tuple(_FLIP)


def expr_selectivity(spec: Dict, stats: PartitionStats,
                     colmap: Sequence[int]) -> Optional[float]:
    """Estimated fraction of rows a predicate spec keeps, or None when
    the shape is inestimable (col-vs-col compares, arithmetic
    predicates).  ``colmap`` maps the expr's column indices back to the
    original partition columns (projections upstream re-number them)."""

    def col_of(s: Dict) -> Optional[int]:
        if s.get("t") == "col" and 0 <= s["i"] < len(colmap):
            orig = colmap[s["i"]]
            if 0 <= orig < stats.ncols:
                return orig
        return None

    def lit_of(s: Dict) -> Optional[float]:
        if s.get("t") == "lit" and isinstance(
                s["v"], (int, float, bool, np.integer, np.floating)):
            return float(s["v"])
        return None

    t = spec["t"]
    if t == "not":
        inner = expr_selectivity(spec["e"], stats, colmap)
        return None if inner is None else 1.0 - inner
    if t == "lit":
        return 1.0 if spec["v"] else 0.0
    if t != "bin":
        return None
    op = spec["op"]
    if op == "&":
        l = expr_selectivity(spec["l"], stats, colmap)
        r = expr_selectivity(spec["r"], stats, colmap)
        return None if l is None or r is None else l * r
    if op == "|":
        l = expr_selectivity(spec["l"], stats, colmap)
        r = expr_selectivity(spec["r"], stats, colmap)
        return None if l is None or r is None else l + r - l * r
    if op not in _CMPS:
        return None
    c, v = col_of(spec["l"]), lit_of(spec["r"])
    if c is None or v is None:         # try  lit <op> col  →  col <flip> lit
        c2, v2 = col_of(spec["r"]), lit_of(spec["l"])
        if c2 is None or v2 is None:
            return None
        c, v, op = c2, v2, _FLIP[op]
    return _cmp_selectivity(op, stats.cols[c], v)


@dataclass(frozen=True)
class FragEstimate:
    selectivity: float            # estimated fraction of rows surviving
    out_bytes: int                # estimated partial size crossing back
    rows_out: float
    exact: bool                   # False when any predicate fell back


def estimate_fragment(frag_spec: Sequence[Dict], stats: PartitionStats
                      ) -> FragEstimate:
    """Walk a fragment spec against partition stats: track the column
    mapping through projections, multiply filter selectivities, and
    size the output partial by the terminal op's merge kind."""
    colmap = list(range(stats.ncols))
    sel, exact = 1.0, True
    key_distinct: Optional[float] = None
    grouped = False
    window: Optional[Dict] = None
    agg: Optional[Dict] = None
    for s in frag_spec:
        kind = s["op"]
        if kind == "filter":
            e = expr_selectivity(s["expr"], stats, colmap)
            if e is None:
                e, exact = DEFAULT_SEL, False
            sel *= e
        elif kind == "select":
            colmap = [colmap[c] if 0 <= c < len(colmap) else -1
                      for c in s["cols"]]
        elif kind == "key_by":
            grouped = True
            k = s["key"]
            if (k.get("t") == "col" and 0 <= k["i"] < len(colmap)
                    and 0 <= colmap[k["i"]] < stats.ncols):
                key_distinct = stats.cols[colmap[k["i"]]].distinct
        elif kind == "window":
            window = s
        elif kind == "aggregate":
            agg = s
    rows_out = sel * stats.rows
    if agg is None:
        out = rows_out * max(len(colmap), 1) * stats.itemsize
    elif agg["agg"] == "histogram":
        out = agg["bins"] * 4
    elif grouped:
        groups = min(key_distinct if key_distinct else 64.0,
                     max(rows_out, 1.0))
        # int64 keys + payload (mean ships (sum, count) pairs)
        out = groups * (8 + (12 if agg["agg"] == "mean" else 8))
    elif window is not None:
        slide = window["slide"] or window["size"]
        out = max(rows_out / max(slide, 1), 1.0) * 8
    else:
        out = 24                   # scalar partial
    return FragEstimate(sel, int(out), rows_out, exact)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NetworkModel:
    """Caller↔store interconnect (same parameters bench_analytics
    models latency with)."""
    bw: float = 1e9               # bytes/s
    latency_s: float = 50e-6      # per-partition RPC


@dataclass(frozen=True)
class ComputeModel:
    """Relative compute throughput: storage-side executors are the
    store's (weaker, shared) CPUs; the caller is the compute cluster."""
    store_bps: float = 2e9        # bytes/s a store node filters/reduces
    caller_bps: float = 8e9       # bytes/s the caller does
    contention_beta: float = 1.0  # how strongly heat discounts the store


@dataclass(frozen=True)
class Decision:
    """One partition's costed placement."""
    mode: str                     # ship | fetch | cached
    est_ship_s: float
    est_fetch_s: float
    est_moved: int                # predicted bytes crossing to caller
    selectivity: Optional[float]  # None = no stats (cold start)
    reason: str

    @property
    def est_s(self) -> float:
        if self.mode == CACHED:
            return 0.0
        return self.est_ship_s if self.mode == SHIP else self.est_fetch_s


class CostModel:
    """Per-partition ship-vs-fetch decision from tier parameters,
    contention, and selectivity statistics.

        scan_s  = tier.latency + size / tier.read_bw          (both modes)
        ship_s  = scan_s + size / (store_bps / (1 + β·load))
                  + net.latency + est_out / net.bw
        fetch_s = scan_s + net.latency + size / net.bw
                  + size / caller_bps

    No stats → ship (cold-start fallback: the always-push behaviour,
    and the shipped fragment piggybacks stats for next time).
    """

    def __init__(self, net: Optional[NetworkModel] = None,
                 compute: Optional[ComputeModel] = None):
        self.net = net or NetworkModel()
        self.compute = compute or ComputeModel()

    def decide(self, frag_spec: Sequence[Dict], *,
               stats: Optional[PartitionStats], size: int,
               tier: Optional[TierParams], load: float = 0.0,
               observed_sel: Optional[float] = None) -> Decision:
        net, comp = self.net, self.compute
        scan_s = tier.read_s(size) if tier else size / 1e9
        store_bps = comp.store_bps / (1.0 + comp.contention_beta
                                      * max(load, 0.0))
        fetch_s = (scan_s + net.latency_s + size / net.bw
                   + size / comp.caller_bps)
        if stats is None:
            ship_s = scan_s + size / store_bps + net.latency_s
            return Decision(SHIP, ship_s, fetch_s, 0, None,
                            "cold start: no partition stats, "
                            "defaulting to pushdown")
        est = estimate_fragment(frag_spec, stats)
        sel, how = est.selectivity, "sel"
        if observed_sel is not None:
            # an actually-observed selectivity for this exact fragment
            # beats the uniform-range estimate: rescale the predicted
            # partial size by observed/estimated
            sel, how = observed_sel, "obs_sel"
            scale = observed_sel / max(est.selectivity, 1e-9)
            out = min(int(est.out_bytes * min(scale, 1e6)), max(size, 1))
        else:
            out = min(est.out_bytes, max(size, 1))
        ship_s = scan_s + size / store_bps + net.latency_s + out / net.bw
        if ship_s <= fetch_s:
            return Decision(
                SHIP, ship_s, fetch_s, out, sel,
                f"{how}={sel:.3f} est_out={out}B: "
                "partial is cheaper to move than raw bytes")
        return Decision(
            FETCH, ship_s, fetch_s, size, sel,
            f"{how}={sel:.3f} est_out={out}B: pushdown "
            "pointless, raw bytes cross either way and caller computes "
            "faster")


# ---------------------------------------------------------------------------
# placement context (plan.optimize hook)
# ---------------------------------------------------------------------------

@dataclass
class CostContext:
    """Everything ``plan.optimize`` needs to place a query's partitions:
    the cost model, the stats catalog, the live store (tier map +
    sizes), per-partition contention, and a probe into the engine's
    partial cache.  Built by the executor per query; ``place`` is pure
    (the executor records the ADDB trace after planning)."""

    model: CostModel
    store: Any
    oids: Sequence[str]
    catalog: Optional[StatsCatalog] = None
    load: Dict[str, float] = field(default_factory=dict)
    cache_probe: Optional[Callable[[str, str], bool]] = None
    tiers: Optional[Dict[str, TierParams]] = None
    # per-partition TierParams override — the cluster planner maps each
    # partition to the *owning node's* tier parameters (blended with the
    # node's observed fragment bandwidth), which a store-global tier map
    # cannot express
    tier_of: Optional[Callable[[str], Optional[TierParams]]] = None

    def place(self, plan) -> Dict[str, Decision]:
        """Per-partition decisions for a PhysicalPlan (duck-typed:
        anything with ``frag_spec``)."""
        tiers = self.tiers or tier_params(self.store)
        frag_key = frag_cache_key(plan.frag_spec)
        # fusible fragments scan only the columns they read: on colblock
        # partitions the ranged read prices in at the pruned byte count
        from repro.analytics.plan import frag_columns, prunable_columns
        frag_cols = frag_columns(plan.frag_spec)
        out: Dict[str, Decision] = {}
        for oid in self.oids:
            if self.cache_probe is not None and self.cache_probe(frag_key,
                                                                 oid):
                out[oid] = Decision(CACHED, 0.0, 0.0, 0, None,
                                    "fresh cached partial for this "
                                    "fragment + object version")
                continue
            try:
                if self.tier_of is not None:
                    tier = self.tier_of(oid)
                else:
                    tier = tiers.get(self.store.meta(oid).layout.tier)
                size = self.store.read_size(oid)
                if frag_cols is not None:
                    attrs = self.store.meta(oid).attrs
                    cols = prunable_columns(plan.frag_spec, attrs)
                    if cols is not None:
                        from repro.core.columnar import column_nbytes
                        size = column_nbytes(attrs, cols)
            except KeyError:
                out[oid] = Decision(SHIP, 0.0, 0.0, 0, None,
                                    "object meta unavailable")
                continue
            stats = self.catalog.get(oid) if self.catalog else None
            obs_sel = (self.catalog.observed_selectivity(frag_key, oid)
                       if self.catalog else None)
            out[oid] = self.model.decide(plan.frag_spec, stats=stats,
                                         size=size, tier=tier,
                                         load=self.load.get(oid, 0.0),
                                         observed_sel=obs_sel)
        return out


def frag_cache_key(frag_spec: Sequence[Dict]) -> str:
    """Canonical identity of a fragment — the partial-cache key prefix
    (full key adds object id + version)."""
    return json.dumps(list(frag_spec), sort_keys=True, default=str)
