"""RG-LRU linear recurrence — Pallas TPU kernel.

h_t = a_t * h_{t-1} + x_t over the sequence, tiled as
grid = (batch, width_blocks, seq_chunks): the chunk dimension is
sequential with the (1, wb) hidden state carried in VMEM scratch; within
a chunk the recurrence runs as a fori_loop of VPU vector ops over the
chunk's rows (a cumprod reformulation was tried and rejected: P_t
underflows fp32 for small gates — recorded in EXPERIMENTS §Perf notes).

Width blocks default 512 lanes: working set per cell = 3 * L * wb * 4B
≈ 1.5 MiB at L=256 — VMEM-resident, the recurrence never touches HBM
between steps (the whole point of the kernel vs the XLA associative
scan, which materialises log-depth intermediates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in 0.6; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _rglru_kernel(a_ref, x_ref, h_ref, state_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)             # (L, wb)
    x = x_ref[0].astype(jnp.float32)

    def body(t, h):                              # h: (1, wb)
        h = a[t][None, :] * h + x[t][None, :]
        # jnp scalar (not python int) index: older jax pl.store requires
        # indices with a .shape
        pl.store(h_ref, (jnp.int32(0), pl.dslice(t, 1), slice(None)),
                 h.astype(h_ref.dtype))
        return h

    h_final = jax.lax.fori_loop(0, chunk, body, state_scr[...])
    state_scr[...] = h_final


def rglru_scan_pallas(a: jax.Array, x: jax.Array, h0=None, *,
                      chunk: int = 256, width_block: int = 512,
                      interpret: bool = False) -> jax.Array:
    """a, x: (b, s, w) fp32; optional h0 (b, w).  Returns h (b, s, w)."""
    b, s, w = a.shape
    assert s % chunk == 0
    wb = min(width_block, w)
    assert w % wb == 0
    if h0 is not None:
        # fold h0 into the first step: x0' = x0 + a0 * h0
        x = x.at[:, 0].add(a[:, 0] * h0)

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, w // wb, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, wb), lambda ib, iw, ic: (ib, ic, iw)),
            pl.BlockSpec((1, chunk, wb), lambda ib, iw, ic: (ib, ic, iw)),
        ],
        out_specs=pl.BlockSpec((1, chunk, wb),
                               lambda ib, iw, ic: (ib, ic, iw)),
        out_shape=jax.ShapeDtypeStruct((b, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, wb), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x)
