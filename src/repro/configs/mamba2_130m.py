"""mamba2-130m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import SSD, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    act="silu",
    attn_pattern=(SSD,),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, vocab_size=256, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16,
)
