"""Scale-out cluster — query throughput at 1/4/16 nodes + kill-a-node.

Partitions live on throttled T3 disk (the 8 ms modelled latency sleeps
release the GIL, so per-node executor parallelism shows up as real
wall-clock scaling on one box).  The same pushdown query runs at each
cluster size; throughput is raw partition bytes scanned per second of
query wall time.

The correctness leg is the paper's HA story end-to-end: a node is
killed *mid-scan* (after the second shipped fragment settles), its
devices start failing reads, its own HAMonitor digests the burst and
the cluster evicts it from the ring while the ClusterShipper re-routes
in-flight fragments to replicas — the query result must be
byte-identical to the healthy run, with the re-routes and the eviction
visible in the ADDB traces.

Emits the usual CSV rows plus ``results/BENCH_cluster.json`` (the
machine-readable perf trajectory).
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.analytics import col
from repro.core import layouts as lay
from repro.core.tiers import T3_DISK

DISK = lay.Layout(lay.MIRRORED, T3_DISK, 2)


def _build(n_nodes: int, partitions: int, rows: int, replicas: int):
    from repro.cluster import ClusterClovis
    root = Path(tempfile.mkdtemp(prefix=f"bench_cluster_{n_nodes}n_"))
    cluster = ClusterClovis(root, nodes=n_nodes,
                            replicas=min(replicas, n_nodes), throttle=True)
    rng = np.random.default_rng(7)
    nbytes = 0
    for i in range(partitions):
        arr = rng.normal(size=(rows, 4))
        cluster.put_array(f"part/{i:03d}", arr, container="events",
                          layout=DISK)
        nbytes += arr.nbytes
    return cluster, nbytes


def _query(eng):
    return eng.scan("events").filter(col(1) > 0.0).aggregate("sum",
                                                             value=col(2))


def _run_query(eng):
    t0 = time.perf_counter()
    res = eng.run(_query(eng))
    return res, time.perf_counter() - t0


def _scaling(partitions: int, rows: int, repeats: int) -> list:
    out = []
    for n_nodes in (1, 4, 16):
        cluster, nbytes = _build(n_nodes, partitions, rows, replicas=2)
        # cache off: every repeat must really scan, or later repeats
        # measure the partial cache instead of the cluster
        eng = cluster.analytics(partial_cache_size=0, prefetch_cold=False,
                                use_kernels=False)
        _run_query(eng)          # warmup: fragment trace/compile + stats
        best_s, moved, value = float("inf"), 0, None
        for _ in range(repeats):
            res, wall = _run_query(eng)
            if wall < best_s:
                best_s, moved = wall, res.stats.bytes_moved
            value = res.value
        thpt = nbytes / best_s
        out.append({"nodes": n_nodes, "wall_s": best_s,
                    "scan_bytes": nbytes, "bytes_moved": moved,
                    "throughput_bytes_per_s": thpt,
                    "value": float(value)})
        emit(f"cluster_query_{n_nodes}n", best_s * 1e6,
             f"thpt={thpt / 1e6:.1f}MB/s;moved={moved}B")
        eng.close()
        cluster.close()
    return out


def _kill_a_node(partitions: int, rows: int) -> dict:
    cluster, _ = _build(4, partitions, rows, replicas=2)
    # 2 workers: the scan must still be in flight when the node dies,
    # or there is nothing left to re-route
    eng = cluster.analytics(partial_cache_size=0, prefetch_cold=False,
                            use_kernels=False, max_workers=2)
    healthy, _ = _run_query(eng)
    ref = np.asarray(healthy.value).tobytes()

    # kill the busiest primary after the 2nd fragment settles — mid-scan
    counts: dict = {}
    for oid in cluster.container("events"):
        p = cluster.primary_of(oid)
        counts[p] = counts.get(p, 0) + 1
    victim = max(counts, key=counts.get)
    state = {"ships": 0, "killed": False}

    def killer(res):
        state["ships"] += 1
        if state["ships"] == 2 and not state["killed"]:
            state["killed"] = True
            cluster.kill_node(victim)

    cluster.shipper.add_observer(killer)
    failed, wall = _run_query(eng)
    cluster.shipper.remove_observer(killer)

    identical = np.asarray(failed.value).tobytes() == ref
    reroutes = sum(1 for t in cluster.addb.route_trace() if t["rerouted"])
    evicted = any(t["subject"] == victim and "node" in t["detail"]
                  for t in cluster.addb.ha_trace("evict"))
    under = [o for o in cluster.container("events")
             if len(cluster.live_holders(o)) < 2]
    eng.close()
    cluster.close()
    result = {"victim": victim, "byte_identical": bool(identical),
              "rerouted_fragments": reroutes, "node_evicted": bool(evicted),
              "under_replicated_after": len(under), "wall_s": wall}
    emit("cluster_kill_a_node", wall * 1e6,
         f"identical={identical};reroutes={reroutes};evicted={evicted}")
    if not identical:
        raise AssertionError(
            "kill-a-node returned a different result than the healthy run")
    if not reroutes:
        raise AssertionError("no re-routed fragments in the ADDB trace")
    return result


def run(partitions: int = 32, rows: int = 4096, repeats: int = 2) -> dict:
    results = {"scaling": _scaling(partitions, rows, repeats),
               "kill_a_node": _kill_a_node(partitions, rows)}
    out = Path("results")
    out.mkdir(exist_ok=True)
    path = out / "BENCH_cluster.json"
    path.write_text(json.dumps(results, indent=2))
    emit("cluster_bench_json", 0.0, str(path))
    return results


if __name__ == "__main__":
    run()
