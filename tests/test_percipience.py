"""Percipience subsystem tests: heat kernel vs numpy reference, Markov
prediction, prefetch hit-rate vs the reactive baseline, byte budget,
ADDB windowed arrays, and pluggable HSM scoring."""
import time

import numpy as np
import pytest

from repro.core import CountingScorer, HsmDaemon, Layout
from repro.core import layouts as lay
from repro.core.hsm import DEMOTE, PROMOTE
from repro.core.tiers import T1_NVRAM, T2_FLASH, T3_DISK
from repro.percipience import (FeatureExtractor, PercipientPolicy,
                               Prefetcher, attach_percipience, heat_scores,
                               markov_predict)
from repro.percipience.heat import heat_scores_ref

FAST = (T1_NVRAM, T2_FLASH)


# ---------------------------------------------------------------------------
# heat kernel
# ---------------------------------------------------------------------------

def test_heat_kernel_matches_numpy_reference(rng):
    n, L = 37, 24                       # deliberately off tile multiples
    now = time.time()
    ts = np.sort(now - rng.uniform(0, 900, (n, L)), axis=1)
    mask = np.ones((n, L))
    for i in range(n):                  # variable-length histories
        k = int(rng.integers(0, L + 1))
        mask[i, :L - k] = 0.0
        ts[i, :L - k] = 0.0
    got = heat_scores(ts, mask, now, half_life_s=120.0, interpret=True)
    want = heat_scores_ref(ts, mask, now, half_life_s=120.0)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_heat_kernel_weighted_and_empty(rng):
    now = time.time()
    ts = np.array([[now - 10, now - 5], [0.0, 0.0]])
    mask = np.array([[1.0, 1.0], [0.0, 0.0]])
    w = np.array([[2.0, 3.0], [1.0, 1.0]])
    got = heat_scores(ts, mask, now, 60.0, weights=w, interpret=True)
    want = heat_scores_ref(ts, mask, now, 60.0, weights=w)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    assert got[1] == 0.0                # no accesses -> zero heat
    assert heat_scores(np.zeros((0, 4)), np.zeros((0, 4)), now,
                       interpret=True).shape == (0,)


def test_heat_decays_over_time():
    now = time.time()
    ts = np.array([[now - 1.0]])
    mask = np.ones((1, 1))
    fresh = heat_scores(ts, mask, now, half_life_s=10.0, interpret=True)[0]
    stale = heat_scores(ts, mask, now + 100.0, half_life_s=10.0,
                        interpret=True)[0]
    assert fresh > 0.9 and stale < 1e-3


# ---------------------------------------------------------------------------
# Markov predictor
# ---------------------------------------------------------------------------

def test_markov_predictor_learns_repeating_trace():
    ex = FeatureExtractor(max_objects=8)
    cycle = ["a", "b", "c", "d"]
    for _ in range(5):
        for oid in cycle:
            ex.on_read(oid, 100)
    probs = ex.transition_matrix()
    correct = 0
    for i, oid in enumerate(cycle):
        nxt = cycle[(i + 1) % len(cycle)]
        preds = markov_predict(probs, ex.bucket_of(oid), k=1)
        assert preds, f"no prediction for {oid}"
        if preds[0][0] == ex.bucket_of(nxt):
            correct += 1
    assert correct == len(cycle)        # 100% on a deterministic cycle
    assert preds[0][1] > 0.9            # and confident


def test_markov_zero_row_predicts_nothing():
    probs = np.zeros((4, 4))
    assert markov_predict(probs, 2, k=3, min_p=0.01) == []


# ---------------------------------------------------------------------------
# feature extractor
# ---------------------------------------------------------------------------

def test_extractor_history_tensors_and_gaps(sage):
    ex = FeatureExtractor(hist_len=8).attach(sage.store)
    sage.create("t/a", block_size=256)
    sage.put("t/a", b"z" * 1024)
    sage.get("t/a")
    oids, ts, sz, mask = ex.history_tensors()
    assert "t/a" in oids
    i = oids.index("t/a")
    assert mask[i].sum() >= 1
    assert (ts[i][mask[i] > 0] > 0).all()
    assert sz[i][mask[i] > 0].sum() > 0
    _, gaps, gmask = ex.inter_arrival_gaps()
    assert gaps.shape == ts.shape and (gaps >= 0).all()


def test_extractor_coalesces_block_fanout(sage):
    """One multi-block read lands as one access, not one per block/replica."""
    ex = FeatureExtractor(hist_len=16).attach(sage.store)
    sage.create("t/b", block_size=256)
    sage.put("t/b", b"q" * 2048)        # 8 blocks
    before = ex.access_count("t/b")
    sage.get("t/b")
    assert ex.access_count("t/b") - before <= 2


# ---------------------------------------------------------------------------
# HSM pluggable scoring
# ---------------------------------------------------------------------------

def test_hsm_default_scoring_unchanged(sage):
    """Regression: the extracted CountingScorer reproduces the daemon's
    historical promote-hot / demote-cold behaviour."""
    hsm = HsmDaemon(sage.store)
    assert isinstance(hsm.scorer, CountingScorer)
    sage.put_array("hot/x", np.ones(100, np.float32),
                   layout=Layout(lay.STRIPED, T2_FLASH, 2))
    for _ in range(3):
        sage.get_array("hot/x")
    hsm.scan_once()
    assert sage.store.meta("hot/x").layout.tier == T1_NVRAM
    sage.store.meta("hot/x").last_access -= 10_000
    sage.store.meta("hot/x").access_count = 0
    hsm.scan_once()
    assert sage.store.meta("hot/x").layout.tier == T2_FLASH


def test_hsm_scorer_hook_overrides_decisions(sage):
    class Never:
        def decide(self, meta, now):
            return None

    class AlwaysDemote:
        def decide(self, meta, now):
            return DEMOTE

    sage.put_array("s/x", np.ones(10, np.float32),
                   layout=Layout(lay.STRIPED, T2_FLASH, 2))
    for _ in range(5):
        sage.get_array("s/x")           # hot by counting standards
    assert HsmDaemon(sage.store, scorer=Never()).scan_once() == 0
    assert sage.store.meta("s/x").layout.tier == T2_FLASH
    HsmDaemon(sage.store, scorer=AlwaysDemote()).scan_once()
    assert sage.store.meta("s/x").layout.tier == T3_DISK


def test_percipient_policy_promotes_hot_demotes_stale(sage):
    ex = FeatureExtractor().attach(sage.store)
    sage.create("p/hot", block_size=256)
    sage.put("p/hot", b"h" * 1024)
    sage.create("p/cold", block_size=256)
    sage.put("p/cold", b"c" * 1024)
    for _ in range(5):
        sage.get("p/hot")
        time.sleep(0.03)                # defeat coalescing
    pol = PercipientPolicy(ex, half_life_s=60.0, promote_heat=2.0,
                           demote_heat=0.5, interpret=True)
    now = time.time()
    assert pol.decide(sage.store.meta("p/hot"), now) == PROMOTE
    # cold object: only its write is in history; far future -> heat ~ 0
    assert pol.decide(sage.store.meta("p/cold"), now + 3600) == DEMOTE


def test_watermark_eviction_ranks_victims_by_heat(sage):
    """Under watermark pressure a heat-aware scorer evicts the coldest-
    by-heat object first, even when raw LRU order disagrees."""
    from repro.core import HsmPolicy

    class HeatOnly:
        heat = {"e/cold": 0.01, "e/hot": 9.0}

        def decide(self, meta, now):
            return None                  # pressure path only

        def heat_of(self, oid, now=None):
            return self.heat.get(oid, 1.0)

    for oid in ("e/cold", "e/hot"):
        sage.put_array(oid, np.ones(64, np.float32),
                       layout=Layout(lay.STRIPED, T2_FLASH, 2))
    # LRU would pick e/hot (older last_access); heat must win instead
    sage.store.meta("e/hot").last_access -= 1_000
    hsm = HsmDaemon(sage.store, policy=HsmPolicy(high_watermark=0.0),
                    scorer=HeatOnly())
    hsm.scan_once()
    from_t2 = [oid for oid, src, _ in hsm.migrations if src == T2_FLASH]
    assert from_t2 and from_t2[0] == "e/cold"


def test_watermark_eviction_does_not_conflate_unknown_with_cold(sage):
    """A never-observed object read recently must outrank (survive) an
    observed object whose heat has decayed — PercipientPolicy.victim_rank
    scores the unknown by a single-access proxy at last_access instead
    of heat 0."""
    from repro.core import HsmPolicy

    # u/fresh exists before the extractor attaches: pre-attach traffic
    # is exactly the "never observed" case
    sage.put_array("u/fresh", np.ones(64, np.float32),
                   layout=Layout(lay.STRIPED, T2_FLASH, 2))
    ex = FeatureExtractor().attach(sage.store)
    pol = PercipientPolicy(ex, half_life_s=0.05, interpret=True)
    sage.put_array("u/observed", np.ones(64, np.float32),
                   layout=Layout(lay.STRIPED, T2_FLASH, 2))
    sage.get_array("u/observed")        # observed...
    time.sleep(0.3)                     # ...but heat fully decayed
    now = time.time()
    sage.store.meta("u/fresh").last_access = now   # recently touched
    assert ex.access_count("u/fresh") == 0
    assert pol.victim_rank(sage.store.meta("u/fresh"), now) > \
        pol.victim_rank(sage.store.meta("u/observed"), now)
    hsm = HsmDaemon(sage.store, policy=HsmPolicy(high_watermark=0.0),
                    scorer=pol)
    hsm._relieve_pressure()
    from_t2 = [oid for oid, src, _ in hsm.migrations if src == T2_FLASH]
    assert from_t2 and from_t2[0] == "u/observed"


def test_watermark_eviction_lru_fallback_without_heat(sage):
    """Scorers without heat_of keep the historical LRU victim order."""
    from repro.core import HsmPolicy

    for oid in ("l/new", "l/old"):
        sage.put_array(oid, np.ones(64, np.float32),
                       layout=Layout(lay.STRIPED, T2_FLASH, 2))
    sage.store.meta("l/old").last_access -= 1_000
    hsm = HsmDaemon(sage.store, policy=HsmPolicy(high_watermark=0.0))
    hsm.scan_once()
    from_t2 = [oid for oid, src, _ in hsm.migrations if src == T2_FLASH]
    assert from_t2 and from_t2[0] == "l/old"


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------

def _populate(sage, n, obj_bytes=4096, block=1024):
    for i in range(n):
        sage.create(f"o/{i}", block_size=block,
                    layout=Layout(lay.STRIPED, T3_DISK, 2))
        sage.put(f"o/{i}", bytes(obj_bytes))


def test_prefetcher_respects_byte_budget(sage):
    ex = FeatureExtractor().attach(sage.store)
    budget = 6000                       # fits one 4KiB object, not two
    pf = Prefetcher(sage.store, ex, byte_budget=budget, sync=True,
                    top_k=4, min_confidence=0.0).attach()
    _populate(sage, 6)
    # interleaved trace gives bucket 0 a 4-way successor fan-out, so one
    # read of o/0 tries to stage several objects at once
    for rep in range(3):
        for i in (1, 2, 3, 4):
            sage.get("o/0")
            sage.get(f"o/{i}")
    assert pf.staged_bytes <= budget
    assert pf.stats()["skipped_budget"] > 0


def test_prefetch_hit_rate_beats_reactive_on_zipf(tmp_path):
    from repro.core.addb import Addb
    from repro.core.clovis import Clovis

    rng = np.random.default_rng(7)
    n, n_reads = 24, 200
    p = 1.0 / np.arange(1, n + 1) ** 1.2
    p /= p.sum()
    trace = rng.choice(n, size=n_reads, p=p)

    def replay(mode):
        sage = Clovis(tmp_path / f"zipf_{mode}", addb=Addb(),
                      devices_per_tier=3)
        _populate(sage, n)
        if mode == "predictive":
            _, pf, policy = attach_percipience(
                sage, sync=True, byte_budget=16 << 20, top_k=3,
                min_confidence=0.05, half_life_s=60.0)
            daemon = HsmDaemon(sage.store, scorer=policy)
        else:
            daemon = HsmDaemon(sage.store)
        hits = 0
        for step, obj in enumerate(trace):
            if sage.store.meta(f"o/{obj}").layout.tier in FAST:
                hits += 1
            sage.get(f"o/{obj}")
            if (step + 1) % 16 == 0:
                daemon.scan_once()
        return hits / n_reads

    reactive, predictive = replay("reactive"), replay("predictive")
    assert predictive > reactive, (predictive, reactive)


def test_prefetcher_records_outcomes_in_addb(sage):
    ex = FeatureExtractor().attach(sage.store)
    pf = Prefetcher(sage.store, ex, sync=True, min_confidence=0.0).attach()
    _populate(sage, 3)
    for _ in range(3):
        for i in range(3):
            sage.get(f"o/{i}")
    ops = {r.op for r in sage.addb.records()}
    assert "prefetch_stage" in ops and "prefetch_hit" in ops
    assert pf.stats()["hits"] > 0


# ---------------------------------------------------------------------------
# ADDB windowed arrays (satellite)
# ---------------------------------------------------------------------------

def test_addb_window_and_to_arrays(sage):
    sage.create("w/1", block_size=256)
    sage.put("w/1", b"x" * 1024)
    sage.get("w/1")
    arrs = sage.addb.to_arrays(since_s=60.0)
    assert set(arrs) == {"ts", "op", "entity", "device", "nbytes",
                         "latency_s", "ok"}
    assert len(arrs["ts"]) == len(arrs["op"]) > 0
    assert arrs["ts"].dtype == np.float64 and arrs["ok"].all()
    gets = sage.addb.to_arrays(since_s=60.0, op="get")
    assert set(gets["op"]) <= {"get"} and (gets["entity"] == "w/1").all()
    assert sage.addb.window(0.0) == []  # empty window -> no records
    assert len(sage.addb.window(60.0)) == len(arrs["ts"])
