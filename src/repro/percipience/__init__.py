"""Percipience — the telemetry→prediction→action loop (paper's title
claim; the SAGE follow-up's ADDB-driven self-optimisation goal).

Data flow:

    ADDB records ─┐
    read hook    ─┼→ FeatureExtractor ─→ heat_scores (Pallas kernel)
    FDMI events  ─┘         │                  │
                            │                  └→ PercipientPolicy → HsmDaemon
                            └→ transition matrix → markov_predict → Prefetcher

``attach_percipience(clovis)`` wires the whole loop onto a Clovis stack.
"""
from repro.percipience.advisor import PercipientPolicy  # noqa: F401
from repro.percipience.heat import (heat_scan_pallas, heat_scores,  # noqa: F401
                                    heat_scores_ref, markov_predict,
                                    markov_topk)
from repro.percipience.prefetcher import Prefetcher  # noqa: F401
from repro.percipience.telemetry import FeatureExtractor  # noqa: F401


def attach_percipience(clovis, *, byte_budget: int = 64 << 20,
                       half_life_s: float = 120.0, sync: bool = False,
                       **prefetch_kw):
    """Wire extractor + prefetcher + percipient HSM scorer onto a Clovis
    stack.  Returns (extractor, prefetcher, policy)."""
    extractor = FeatureExtractor().attach(clovis.store)
    prefetcher = Prefetcher(clovis.store, extractor,
                            byte_budget=byte_budget, sync=sync,
                            **prefetch_kw).attach()
    policy = PercipientPolicy(extractor, half_life_s=half_life_s)
    return extractor, prefetcher, policy
