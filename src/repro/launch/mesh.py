"""Production mesh definitions.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; everything else (tests, benches, examples) sees the real
single CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods for the multi-pod dry run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over local devices (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
