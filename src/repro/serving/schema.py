"""Request/response schemas for the multi-tenant query front door.

SAGE's premise is that exascale storage *serves* analysis — many
concurrent consumers hitting one percipient store, not a single batch
job (paper §1; the ROADMAP's "millions of users" north star).  A front
door needs a wire-shaped contract: queries arrive as **declarative op
specs** (the same JSON-able specs shipped fragments already use, see
analytics/plan.py), never as closures, so a request can be validated —
and rejected — before it touches a single object.

``QueryRequest`` carries the tenant, the target container, the op-spec
chain, and an optional deadline.  ``validate_request`` replays the
Dataset API's chain rules over the specs (aggregate must be terminal,
nothing but an aggregate may follow key_by/window, histogram needs a
fixed vrange, ...) and raises a typed ``ValidationError`` on any
malformed plan.  ``TenantConfig`` is the admission contract: priority
(weighted-fair share), byte + compute token-bucket quotas, queue bound,
and a default deadline (admission.py charges and enforces them).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analytics.plan import (AGGS, Aggregate, KeyBy, MapRows, Op,
                                  Window, op_from_spec, op_to_spec, optimize)

MAX_OPS = 64                      # longest accepted op chain (abuse bound)


class ServingError(RuntimeError):
    """Base class for every typed front-door error."""


class ValidationError(ServingError):
    """Malformed request: rejected before touching the store."""


@dataclass(frozen=True)
class TenantConfig:
    """Admission contract of one tenant.

    ``priority`` weights the deficit-round-robin fair queue (a tenant
    with priority 2.0 drains twice the bytes per round of a tenant with
    1.0).  ``byte_quota_per_s`` / ``compute_quota_per_s`` refill the
    tenant's token buckets (bytes scanned at the store, and estimated
    store-compute seconds); ``*_burst`` caps the bucket (defaults to
    4 s of refill).  ``max_queue`` bounds the tenant's admitted-but-
    unexecuted backlog — beyond it, submissions shed with
    ``AdmissionRejected``.  ``deadline_s`` is the default per-query
    deadline (a queued query past its deadline sheds instead of
    executing — tail-latency protection for everyone behind it).
    """
    tenant_id: str
    priority: float = 1.0
    byte_quota_per_s: float = float("inf")
    byte_burst: Optional[float] = None
    compute_quota_per_s: float = float("inf")
    compute_burst: Optional[float] = None
    max_queue: int = 256
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if not self.tenant_id or not isinstance(self.tenant_id, str):
            raise ValidationError("tenant_id must be a non-empty string")
        if not self.priority > 0:
            raise ValidationError("priority must be > 0")
        if self.max_queue < 1:
            raise ValidationError("max_queue must be >= 1")
        for q in (self.byte_quota_per_s, self.compute_quota_per_s):
            if not q > 0:
                raise ValidationError("quotas must be > 0 (use inf for "
                                      "unmetered)")


@dataclass(frozen=True)
class QueryRequest:
    """One front-door query: tenant + container + op-spec chain.

    ``ops`` is a tuple of JSON-able op specs (``plan.op_to_spec``
    shapes) — the declarative form lets the front door validate, admit,
    fingerprint (plan cache), and dedup (fragment single-flight)
    without executing anything.  ``deadline_s`` overrides the tenant's
    default; ``tag`` labels the ADDB serving trace.
    """
    tenant: str
    container: str
    ops: Tuple[Dict, ...] = ()
    deadline_s: Optional[float] = None
    tag: str = ""

    @staticmethod
    def from_dataset(tenant: str, ds, *, deadline_s: Optional[float] = None,
                     tag: str = "") -> "QueryRequest":
        """Build a request from a Dataset chain.  Only spec-able ops
        survive the wire: ``map()`` closures raise ValidationError (a
        remote front door cannot ship arbitrary Python)."""
        from repro.analytics.dataset import ContainerSource
        if not isinstance(ds.source, ContainerSource):
            raise ValidationError(
                "front-door queries scan a container — stream/join "
                "sources have no serializable request form")
        specs = []
        for op in ds.ops:
            if isinstance(op, MapRows):
                raise ValidationError(
                    "map() closures cannot cross the front door; "
                    "express the query with spec-able ops "
                    "(filter/select/key_by/window/aggregate)")
            specs.append(op_to_spec(op))
        return QueryRequest(tenant, ds.source.container, tuple(specs),
                            deadline_s=deadline_s, tag=tag)


@dataclass
class QueryResponse:
    """Front-door result envelope: the value (or typed failure), the
    engine's QueryStats, and the per-stage latency trace
    (admit/queue/plan/execute/merge/total seconds) that makes tail
    latency attributable — the same numbers land in ADDB
    (``Addb.serving_trace``)."""
    tenant: str
    tag: str
    ok: bool
    value: Any = None
    error: str = ""
    shed: bool = False
    stats: Any = None                       # analytics QueryStats (or None)
    trace: Dict[str, float] = field(default_factory=dict)


def validate_ops(ops_spec: Sequence[Dict]) -> List[Op]:
    """Parse + validate an op-spec chain, returning the logical ops.

    Raises ``ValidationError`` for anything the Dataset API itself
    would refuse: unknown ops/aggregates, non-terminal aggregates,
    transforms after key_by/window, grouped histograms, missing
    histogram vrange, windows with non-positive size/slide.  Runs
    entirely on the specs — no store access.
    """
    if not isinstance(ops_spec, (list, tuple)):
        raise ValidationError("ops must be a list of op specs")
    if len(ops_spec) > MAX_OPS:
        raise ValidationError(f"op chain too long (> {MAX_OPS})")
    ops: List[Op] = []
    for i, spec in enumerate(ops_spec):
        if not isinstance(spec, dict) or "op" not in spec:
            raise ValidationError(f"ops[{i}] is not an op spec dict")
        try:
            op = op_from_spec(spec)
        except (KeyError, ValueError, TypeError, IndexError) as e:
            raise ValidationError(f"ops[{i}] malformed: {e}") from e
        ops.append(op)
    grouped = False
    for i, op in enumerate(ops):
        if grouped and not isinstance(op, Aggregate):
            raise ValidationError(
                "only an aggregate may follow key_by/window")
        if isinstance(op, (KeyBy, Window)):
            grouped = True
        if isinstance(op, Window) and (
                op.size <= 0 or (op.slide is not None and op.slide <= 0)):
            raise ValidationError("window size/slide must be positive")
        if isinstance(op, Aggregate):
            if i != len(ops) - 1:
                raise ValidationError("aggregate must be the terminal op")
            if op.agg not in AGGS:
                raise ValidationError(f"unknown aggregate {op.agg!r}")
            if op.agg == "histogram":
                if op.bins <= 0:
                    raise ValidationError("histogram needs bins > 0")
                if op.vrange is None or not op.vrange[0] < op.vrange[1]:
                    raise ValidationError(
                        "histogram needs vrange=(lo, hi) with lo < hi")
    try:
        # reuses the optimizer's own grouping checks (key_by/window
        # require a terminal aggregate, no grouped histograms)
        optimize(ops, pushdown=True)
    except ValueError as e:
        raise ValidationError(str(e)) from e
    return ops


def validate_request(req: QueryRequest,
                     tenants: Optional[Dict[str, TenantConfig]] = None
                     ) -> List[Op]:
    """Full request validation; returns the parsed logical ops.  With a
    tenant table, unknown tenants are rejected here (before any quota
    or store interaction)."""
    if not isinstance(req, QueryRequest):
        raise ValidationError("expected a QueryRequest")
    if not req.tenant or not isinstance(req.tenant, str):
        raise ValidationError("request needs a non-empty tenant id")
    if tenants is not None and req.tenant not in tenants:
        raise ValidationError(f"unknown tenant {req.tenant!r}")
    if not req.container or not isinstance(req.container, str):
        raise ValidationError("request needs a non-empty container name")
    if req.deadline_s is not None and not req.deadline_s > 0:
        raise ValidationError("deadline_s must be > 0")
    return validate_ops(req.ops)
