"""One simulated storage node — a complete single-node SAGE stack.

A node owns its own tier pools (devices on its own directory subtree),
its own ObjectStore + Clovis facade, a FunctionShipper whose executors
model the node's local CPUs, and an HAMonitor watching the node's
devices.  Only the ADDB is shared cluster-wide: telemetry from every
node lands in one trace, which is what lets a benchmark (or operator)
see a query's fragments re-route across nodes.

``kill()`` models abrupt whole-node loss: every device fails at once,
so in-flight local reads raise and escalate through the node's own
HAMonitor — the cluster layer subscribes to those decisions and turns
a burst of device evictions into a ring eviction (cluster.py).
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from repro.core.addb import Addb
from repro.core.clovis import Clovis
from repro.core.function_shipping import FunctionShipper
from repro.core.ha import HAMonitor


class StorageNode:
    def __init__(self, node_id: str, domain: str, root: Path, *,
                 addb: Optional[Addb] = None, devices_per_tier: int = 2,
                 throttle: bool = False, ship_workers: int = 2,
                 ha_error_threshold: int = 2):
        self.node_id = node_id
        self.domain = domain
        self.root = Path(root)
        self.clovis = Clovis(self.root, addb=addb,
                             devices_per_tier=devices_per_tier,
                             throttle=throttle)
        self.store = self.clovis.store
        self.shipper = FunctionShipper(self.clovis, max_workers=ship_workers)
        self.ha = HAMonitor(self.store, error_threshold=ha_error_threshold)
        # True until the cluster evicts the node from the placement ring;
        # a freshly-killed node keeps alive=True so reads still route to
        # it, fail, and drive the organic HA eviction chain
        self.alive = True

    def kill(self):
        """Abrupt node failure: every device fails.  Metadata stays in
        memory (routing still *finds* the node), but any read raises —
        the failure is discovered by traffic, exactly how a real node
        loss surfaces."""
        for pool in self.store.pools.values():
            for d in pool.devices:
                d.fail()

    def close(self):
        self.shipper.shutdown()

    def __repr__(self):
        return (f"StorageNode({self.node_id!r}, domain={self.domain!r}, "
                f"alive={self.alive})")
