"""Edge-ingestion quickstart — exactly-once aggregates from hostile producers.

The streaming tour assumed polite in-process producers; real instrument
ranks crash mid-send, redeliver after a lost ack, and occasionally emit
garbage.  This tour wires the armour from docs/ingestion.md

    instrument → EdgeBuffer (durable WAL) → EdgeIngestor
              → IdempotencyLedger / DeadLetterQueue → StreamContext
              → continuous query

and abuses it: a redelivered duplicate, a poison event, deliveries the
network ate, and a full producer crash with replay from the on-disk
buffer.  The punchline is the exactly-once invariant: the streaming
window sums come out byte-identical to a batch recomputation of the
logical events, as if nothing had gone wrong.

    PYTHONPATH=src python examples/edge_tour.py
"""
import tempfile
from collections import defaultdict
from pathlib import Path

import numpy as np

from repro.analytics import EventWindow, col
from repro.core import Clovis, StreamContext
from repro.edge import (DeadLetterQueue, EdgeBuffer, EdgeIngestor,
                        IdempotencyLedger, encode_array)

WINDOW_S = 1.0


def main():
    root = Path(tempfile.mkdtemp(prefix="sage_edge_"))
    cl = Clovis(root / "store", devices_per_tier=3)
    eng = cl.analytics()

    ctx = StreamContext(n_producers=2)
    cq = eng.run_continuous(
        eng.from_stream(ctx)
           .key_by(col(0))                     # per sensor
           .aggregate("sum", value=col(1)),
        EventWindow(size_s=WINDOW_S, allowed_lateness_s=0.5),
        delta_rows=64)

    # shared store-side state: one dedup ledger, one dead-letter queue
    ledger, dlq = IdempotencyLedger(), DeadLetterQueue()

    def make_ingestor(p):
        buf = EdgeBuffer(root / "edge" / f"rank{p}", source=f"rank{p}",
                         segment_bytes=2048)
        return EdgeIngestor(ctx, buf, producer=p, ledger=ledger, dlq=dlq,
                            addb=cl.addb)

    ingestors = [make_ingestor(p) for p in (0, 1)]

    # ground truth: every *logical* event, exactly once
    expected = defaultdict(float)

    def record(p, ets, sensor, value):
        expected[(f"rank{p}", ets // WINDOW_S * WINDOW_S, sensor)] += value

    # ---- happy path: two ranks push 4 s of event time ----------------
    rng = np.random.default_rng(0)
    kept_for_redelivery = None
    for i in range(400):
        ets = i * 0.01
        for p in (0, 1):
            sensor, value = int(rng.integers(0, 3)), float(rng.integers(1, 10))
            ing = ingestors[p]
            if p == 0 and 180 <= i < 190:
                # the network eats these deliveries: durably appended,
                # never pushed — only the crash replay below saves them
                ing.buffer.append(f"rank{p}", encode_array([sensor, value]),
                                  event_ts=ets)
            elif p == 1 and i == 100:
                # raw path, keeping the record so we can redeliver the
                # *same* event later (the lost-ack scenario)
                kept_for_redelivery = ing.buffer.append(
                    f"rank{p}", encode_array([sensor, value]), event_ts=ets)
                ing.deliver(kept_for_redelivery)
            else:
                ing.send(f"rank{p}", np.array([sensor, value]), event_ts=ets)
            record(p, ets, sensor, value)       # logical event either way

        if i == 190:                            # rank 0 crashes here
            st = ingestors[0].buffer.stats
            ingestors[0].buffer.close()         # process gone, acks gone
            ingestors[0] = make_ingestor(0)     # restart over the same dir
            replayed = ingestors[0].replay()
            print(f"rank0 crashed with {st['appended'] - st['acked']} "
                  f"unacked record(s); replay applied "
                  f"{replayed['applied']} lost event(s) and absorbed "
                  f"{replayed['duplicate']} duplicate(s)")
            print(f"  pruned {ingestors[0].prune()} fully-acked segment(s); "
                  f"replay is bounded by the unacked window\n")

    # ---- a redelivery after a lost ack: absorbed, not double-counted -
    outcome = ingestors[1].deliver(kept_for_redelivery)
    print(f"rank1 redelivers event #{kept_for_redelivery.event_id}: "
          f"outcome={outcome!r} (ledger floor {ledger.floor('rank1')})")

    # ---- a poison event: routed to the DLQ, never shed ---------------
    outcome = ingestors[1].send("rank1", b"\x89NOT-AN-NPY", event_ts=3.99)
    letter = dlq.drain()[0]
    print(f"rank1 emits garbage: outcome={outcome!r}, dead-lettered "
          f"with reason {letter.reason.split('(')[0].strip()!r} "
          f"(dlq.published={dlq.published})\n")

    # ---- close and check the invariant -------------------------------
    ctx.close()
    results = list(cq.drain()) + list(cq.close())
    streamed = {}
    for r in results:
        keys, sums = r.value
        for k, s in zip(keys.tolist(), sums.tolist()):
            streamed[(r.stream_id, r.start, k)] = s

    batch = {k: v for k, v in expected.items()}
    assert streamed == batch, "exactly-once invariant violated"
    print(f"{len(results)} windows emitted; streaming sums == batch "
          "recomputation of the logical events: exactly-once holds")
    print(f"  rank0 ingest counters: {ingestors[0].stats}")
    print(f"  ADDB edge trace: {len(cl.addb.edge_trace())} records "
          f"({len(cl.addb.edge_trace('replay'))} replay, "
          f"{len(cl.addb.edge_trace('dlq'))} dlq)")
    eng.close()


if __name__ == "__main__":
    main()
