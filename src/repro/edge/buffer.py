"""EdgeBuffer — durable producer-side replay buffer (paper §1, §4.2).

SAGE's data arrives from "large, dispersed scientific instruments and
sensors"; the instrument side of that pipe fails in every way a
network-attached embedded box can: crash mid-send, redeliver after an
ack was lost, corrupt a record, die halfway through writing one.  The
EdgeBuffer is the producer's write-ahead log against all of that: every
event is appended to a checksummed segment file *before* delivery into
the store's StreamContext, so a crashed producer replays from disk
instead of losing data, and the store-side idempotency ledger
(``repro.edge.ledger``) turns the resulting at-least-once delivery into
exactly-once window aggregates.

Segment format (docs/ingestion.md):

    segment file  seg-<first_event_id 012d>.log
    record        u32 body_len | u32 crc32(body) | body
    body          u64 event_id | f64 event_ts |
                  u16 stream_id_len | stream_id utf-8 | payload bytes

Durability/atomicity contract:

  * a record is written in one ``write()`` call and flushed; a crash
    mid-append can only produce a **torn tail** — a truncated final
    record in the final segment.  ``replay()``/open detect it (short
    read or checksum mismatch at EOF) and truncate the file back to the
    last intact record, so earlier records are never corrupted by a
    crash (``stats["torn_tail_recovered"]`` counts recoveries);
  * checksum damage *before* the tail is real corruption (bad media,
    truncated copy) and raises ``EdgeBufferCorruption`` — silently
    skipping records would break exactly-once accounting;
  * ``ack(event_id)`` marks an event delivered; ``prune()`` deletes
    only segments whose every record is acked, so replay after a crash
    is bounded by the unacked window, not the stream's history.  Acks
    are in-memory on purpose: losing them re-replays acked events,
    which the ledger absorbs (at-least-once buffer + dedup ledger =
    exactly-once pipeline).
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional

_HEADER = struct.Struct("<II")          # body_len, crc32
_BODY_FIXED = struct.Struct("<QdH")     # event_id, event_ts, stream_id_len


class EdgeBufferCorruption(RuntimeError):
    """A non-tail record failed its checksum — the segment is damaged
    beyond what a torn append can explain."""


@dataclass(frozen=True)
class EdgeRecord:
    """One durable edge event: ``event_id`` is the buffer-assigned
    monotonic id (the idempotency key, scoped by the buffer's
    ``source``), ``payload`` the raw encoded bytes (decoding — and
    poison detection — happens at ingest, not at storage)."""
    event_id: int
    stream_id: str
    event_ts: float
    payload: bytes

    def encode(self) -> bytes:
        sid = self.stream_id.encode()
        body = (_BODY_FIXED.pack(self.event_id, self.event_ts, len(sid))
                + sid + self.payload)
        return _HEADER.pack(len(body), zlib.crc32(body)) + body


def _decode_body(body: bytes) -> EdgeRecord:
    eid, ets, sid_len = _BODY_FIXED.unpack_from(body)
    off = _BODY_FIXED.size
    sid = body[off:off + sid_len].decode()
    return EdgeRecord(eid, sid, ets, body[off + sid_len:])


class EdgeBuffer:
    """Append-only, checksummed, prunable segment log for one producer.

    Thread-safety: one producer thread appends; ``ack``/``prune`` may
    be called from the delivery path (same or another thread) — all
    state is guarded by one lock.  Reopening an existing directory
    recovers: segments are scanned, a torn tail is truncated, and the
    next event id continues after the last durable record.
    """

    def __init__(self, root, *, source: str = "edge",
                 segment_bytes: int = 1 << 16, fsync: bool = False):
        if segment_bytes <= 0:
            raise ValueError("segment_bytes must be positive")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.source = source
        self.segment_bytes = segment_bytes
        self.fsync = fsync
        self._lock = threading.Lock()
        self._acked: set = set()
        self._acked_floor = -1          # every id <= floor is acked
        self._counts = {"appended": 0, "acked": 0, "pruned_segments": 0,
                        "torn_tail_recovered": 0, "replayed": 0}
        self._fh = None
        self._next_id = 0
        self._recover()

    # -- recovery ------------------------------------------------------

    def _segments(self) -> List[Path]:
        return sorted(self.root.glob("seg-*.log"))

    def _recover(self):
        """Scan existing segments, truncating a torn tail on the last
        one, and position the next event id after the last record."""
        segs = self._segments()
        for i, seg in enumerate(segs):
            last_tail = i == len(segs) - 1
            for rec in self._read_segment(seg, truncate_torn=last_tail):
                self._next_id = max(self._next_id, rec.event_id + 1)

    def _read_segment(self, seg: Path, *, truncate_torn: bool
                      ) -> Iterator[EdgeRecord]:
        data = seg.read_bytes()
        off = 0
        while off < len(data):
            torn = True
            if off + _HEADER.size <= len(data):
                blen, crc = _HEADER.unpack_from(data, off)
                body = data[off + _HEADER.size: off + _HEADER.size + blen]
                if len(body) == blen and zlib.crc32(body) == crc:
                    torn = False
            if torn:
                tail_of_file = True      # any damage reaching EOF is torn
                if off + _HEADER.size <= len(data):
                    blen, _ = _HEADER.unpack_from(data, off)
                    tail_of_file = off + _HEADER.size + blen >= len(data)
                if truncate_torn and tail_of_file:
                    with seg.open("r+b") as fh:
                        fh.truncate(off)
                    with self._lock:
                        self._counts["torn_tail_recovered"] += 1
                    return
                raise EdgeBufferCorruption(
                    f"{seg.name}: corrupt record at offset {off} "
                    f"(not a recoverable torn tail)")
            yield _decode_body(body)
            off += _HEADER.size + blen

    # -- append path ---------------------------------------------------

    def append(self, stream_id: str, payload: bytes, *,
               event_ts: float = 0.0) -> EdgeRecord:
        """Durably append one event and return its record (with the
        assigned event id).  The record is on disk before this
        returns — deliver *after* appending, never before."""
        if not isinstance(payload, (bytes, bytearray)):
            raise TypeError("payload must be bytes — encode arrays with "
                            "repro.edge.encode_array")
        with self._lock:
            rec = EdgeRecord(self._next_id, stream_id, float(event_ts),
                             bytes(payload))
            self._next_id += 1
            raw = rec.encode()
            if (self._fh is None
                    or self._fh.tell() + len(raw) > self.segment_bytes):
                self._roll(rec.event_id)
            self._fh.write(raw)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._counts["appended"] += 1
            return rec

    def _roll(self, first_id: int):
        if self._fh is not None:
            self._fh.close()
        path = self.root / f"seg-{first_id:012d}.log"
        self._fh = path.open("ab")

    # -- replay / ack / prune ------------------------------------------

    def replay(self) -> Iterator[EdgeRecord]:
        """Yield every durable, unpruned record in event-id order —
        the crash-recovery path.  A torn tail on the final segment is
        truncated in place; earlier records are yielded intact."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
            segs = self._segments()
        for i, seg in enumerate(segs):
            for rec in self._read_segment(seg,
                                          truncate_torn=i == len(segs) - 1):
                with self._lock:
                    self._counts["replayed"] += 1
                yield rec

    def ack(self, event_id: int):
        """Mark one event delivered (applied, deduplicated, or routed
        to the dead-letter channel — all terminal outcomes)."""
        with self._lock:
            if event_id <= self._acked_floor:
                return
            self._acked.add(event_id)
            self._counts["acked"] += 1
            while self._acked_floor + 1 in self._acked:
                self._acked_floor += 1
                self._acked.discard(self._acked_floor)

    def prune(self) -> int:
        """Delete segments whose every record is acked; returns how
        many segments were removed.  The newest segment is never
        pruned, even when fully acked: it anchors ``next_event_id``
        across reopens — deleting it would restart ids at 0 after a
        crash, and reused ids read as duplicates to the ledger."""
        removed = 0
        with self._lock:
            all_segs = self._segments()
            if len(all_segs) <= 1:
                return 0
            segs = all_segs[:-1]        # never the newest (see above)
            # a segment's records span [its first id, next seg's first)
            bounds = [int(s.stem.split("-")[1]) for s in all_segs]
            for seg, lo, hi in zip(segs, bounds, bounds[1:]):
                if hi - 1 <= self._acked_floor:
                    seg.unlink()
                    removed += 1
                    self._counts["pruned_segments"] += 1
                else:
                    break               # segments are id-ordered
        return removed

    # -- lifecycle / introspection -------------------------------------

    def close(self):
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                if self.fsync:
                    os.fsync(self._fh.fileno())
                self._fh.close()
                self._fh = None

    @property
    def next_event_id(self) -> int:
        with self._lock:
            return self._next_id

    @property
    def stats(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self._counts)
            out["acked_floor"] = self._acked_floor
            out["segments"] = len(self._segments())
            return out
