"""Partition-parallel query executor — SAGE's in-storage analytics run
loop: costed pushdown, tier-aware scheduling, spill (paper §4.1).

Execution of a container query:

  1. the optimizer places each partition independently (cost.py): the
     fused fragment **ships** to the store via ``FunctionShipper``, the
     raw bytes **fetch** to the caller, or a **cached** prior partial is
     reused — chosen from tier latency/bandwidth, percipience heat, and
     selectivity statistics, with cold-start partitions defaulting to
     ship (PR 2's always-push behaviour);
  2. per-object tasks are scheduled tier-aware: partitions already on
     fast tiers (and, when percipience is attached, with high predicted
     heat) run first, while cold slow-tier partitions are promoted in the
     background so their migration overlaps the hot partitions' compute;
  3. per-partition partials merge caller-side (segmented re-reduce for
     group-bys, concat for rows/windows, partial combine for scalars);
  4. join intermediates larger than ``spill_bytes`` grace-partition into
     a spill container placed by RTHMS ``recommend_tier``.

Every placement decision lands in ADDB (op ``analytics_plan``; see
``Addb.plan_trace``) so chosen-plan quality is auditable against the
always-push / always-fetch oracles.  Shipped fragments piggyback
partition-stats summaries when the catalog is stale, so statistics
accrue as a side effect of running queries.

``pushdown=False`` fetches whole objects to the caller and runs the
identical op interpreter locally — the fetch-all baseline the benchmark
compares bytes-moved against.  ``cost_based=False`` restores uniform
always-push (the always-push oracle).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.analytics.cost import (CACHED, FETCH, SHIP, STATS_KEY,
                                  ComputeModel, CostContext, CostModel,
                                  NetworkModel, StatsCatalog, frag_cache_key)
from repro.analytics.dataset import (ContainerSource, Dataset, JoinSource,
                                     LiveStreamSource, StreamSource)
from repro.analytics.plan import (KernelCfg, PhysicalPlan, apply_ops,
                                  compile_fragment, merge_partials, optimize,
                                  optimize_streaming, prunable_columns)
from repro.analytics.streaming import ContinuousQuery, EventWindow
from repro.core import layouts as lay
from repro.core.function_shipping import FunctionShipper
from repro.core.hsm import recommend_tier
from repro.core.tiers import T2_FLASH, T3_DISK, T4_ARCHIVE, TIER_ORDER

_TIER_RANK = {t: i for i, t in enumerate(TIER_ORDER)}
_SLOW_TIERS = (T3_DISK, T4_ARCHIVE)

# distinguishes ADDB decision-trace tags across engines sharing one ADDB
_ENGINE_SEQ = itertools.count(1)


class AnalyticsError(RuntimeError):
    """A partition failed (after the shipper's retry policy)."""


@dataclass
class QueryStats:
    pushdown: bool = True
    partitions: int = 0
    bytes_scanned: int = 0          # raw object bytes read at the store
    bytes_moved: int = 0            # bytes crossing to the caller
    spilled_bytes: int = 0
    prefetched: int = 0             # cold partitions staged during the run
    cache_hits: int = 0             # partitions served from cached partials
    schedule: List[str] = field(default_factory=list)
    decisions: Dict[str, str] = field(default_factory=dict)  # oid -> mode
    query_tag: str = ""             # ADDB decision-trace key (plan_trace)
    plan: str = ""
    wall_s: float = 0.0
    plan_s: float = 0.0             # optimizer/placement time
    exec_s: float = 0.0             # partition execution time
    merge_s: float = 0.0            # caller-side partial merge time
    dedup_hits: int = 0             # fragments shared with an in-flight
                                    # identical query (serving engines)
    pruned_reads: int = 0           # colblock partitions read column-pruned
    double_buffered: int = 0        # fetches overlapped with another
                                    # partition's compute (read-ahead)
    snapshot_version: int = -1      # pinned manifest version (-1: the
                                    # container is not manifest-managed)


@dataclass
class QueryResult:
    value: Any
    stats: QueryStats


def _nbytes(v) -> int:
    """Modelled wire size of a partial crossing store -> caller."""
    if v is None:
        return 0
    if isinstance(v, np.ndarray):
        return v.nbytes
    if isinstance(v, (tuple, list)):
        return sum(_nbytes(x) for x in v)
    if isinstance(v, dict):
        return sum(_nbytes(x) for x in v.values())
    if isinstance(v, str):
        return len(v)
    return 8                       # scalar


class AnalyticsEngine:
    def __init__(self, clovis, *, shipper: Optional[FunctionShipper] = None,
                 pushdown: bool = True, cost_based: bool = True,
                 stats: Optional[StatsCatalog] = None,
                 net: Optional[NetworkModel] = None,
                 compute: Optional[ComputeModel] = None,
                 use_kernels: bool = True,
                 interpret: bool = False, max_workers: int = 4,
                 spill_bytes: int = 4 << 20,
                 spill_container: str = "analytics_spill",
                 prefetch_cold: bool = True,
                 partial_cache_size: int = 128):
        self.clovis = clovis
        self.shipper = shipper or FunctionShipper(clovis,
                                                  max_workers=max_workers)
        self._own_shipper = shipper is None
        self.pushdown = pushdown
        self.cost_based = cost_based
        self._own_stats = stats is None
        self.stats = (stats if stats is not None
                      else StatsCatalog().attach(clovis.store))
        self.stats.attach_shipper(self.shipper)
        self.cost_model = CostModel(net=net, compute=compute)
        self.kcfg = KernelCfg(use_kernel=use_kernels, interpret=interpret)
        self.max_workers = max_workers
        self.spill_bytes = spill_bytes
        self.spill_container = spill_container
        self.prefetch_cold = prefetch_cold
        self._qid = 0
        self._etag = f"analytics/e{next(_ENGINE_SEQ)}"
        self._lock = threading.Lock()
        self._partial_cache: "OrderedDict[Tuple[str, str, int], Any]" = \
            OrderedDict()
        self._partial_cache_size = partial_cache_size
        self._cache_lock = threading.Lock()
        # content can change without a version increase (append keeps the
        # version; delete+recreate resets it), so the version-keyed cache
        # additionally invalidates on store writes and deletes
        clovis.store.register_write_hook(self._cache_invalidate)
        clovis.store.fdmi_register(self._cache_on_fdmi)

    # ------------------------------------------------------------------
    # dataset constructors
    # ------------------------------------------------------------------

    def scan(self, container: str) -> Dataset:
        """Dataset over a Clovis container, one partition per object."""
        return Dataset(self, ContainerSource(container))

    def from_stream(self, tap) -> Dataset:
        """Dataset over a stream source.  A StreamTap (or anything with
        ``partitions()``) batches the drained rows, one partition per
        stream id in sequence order.  A live StreamContext (anything
        with ``subscribe``/``push``) makes the chain a *continuous
        query*: execute it with ``run_continuous``, not ``run``."""
        if hasattr(tap, "subscribe") and hasattr(tap, "push"):
            return Dataset(self, LiveStreamSource(tap))
        return Dataset(self, StreamSource(tap))

    def explain(self, ds: Dataset) -> str:
        src = ds.source
        if isinstance(src, ContainerSource):
            head = f"scan({src.container})"
            oids = self._schedule(self.clovis.container(src.container))
            plan = self._make_plan(ds, oids)
        elif isinstance(src, LiveStreamSource):
            head = "from_stream(live)"
            plan = optimize_streaming(ds.ops)
        elif isinstance(src, StreamSource):
            head = "from_stream"
            plan = optimize(ds.ops, pushdown=False)
        else:
            head = f"join(on={src.on})"
            plan = optimize(ds.ops, pushdown=False)
        return f"{head}\n{plan.describe()}"

    def _can_push(self, ds: Dataset) -> bool:
        return self.pushdown and isinstance(ds.source, ContainerSource)

    # ------------------------------------------------------------------
    # planning (cost-based placement)
    # ------------------------------------------------------------------

    def _make_plan(self, ds: Dataset, oids: List[str]) -> PhysicalPlan:
        push = self._can_push(ds)
        ctx = None
        if push and self.cost_based:
            ctx = CostContext(model=self.cost_model,
                              store=self.clovis.store, oids=oids,
                              catalog=self.stats,
                              load=self._load(oids),
                              cache_probe=self._cache_probe)
        return optimize(ds.ops, pushdown=push, cost_ctx=ctx)

    def _policy_map(self, oids: List[str], method: str) -> Dict[str, float]:
        """Query the percipience policy (clovis.percipience[2]) for a
        per-oid map; {} when percipience is absent or the policy errors
        (prediction is advisory, never load-bearing)."""
        percip = getattr(self.clovis, "percipience", None)
        if not percip:
            return {}
        try:
            return getattr(percip[2], method)(oids)
        except Exception:
            return {}

    def _load(self, oids: List[str]) -> Dict[str, float]:
        """Per-partition storage-side contention from percipience heat
        (empty when percipience is not attached)."""
        return self._policy_map(oids, "load_factor")

    # -- manifest snapshot pinning -------------------------------------

    def _pin_snapshot(self, container: str):
        """Pin the container's current manifest version for the whole
        query, so the partition list and every block stay immutable
        while appends and compactions commit underneath (pinned blocks
        survive GC).  None for containers without a manifest — they
        behave exactly as before the compaction subsystem existed."""
        registry = getattr(self.clovis, "manifests", None)
        if registry is None:
            return None
        manifest = registry.lookup(container)
        if manifest is None:
            return None
        return (manifest, manifest.pin())

    @staticmethod
    def _unpin_snapshot(pin):
        if pin is not None:
            pin[0].unpin(pin[1])

    # -- partial cache (fragment results keyed by object version) ------

    def _cache_invalidate(self, oid: str, nbytes: int = 0):
        """Drop every cached partial for ``oid`` — store write hook
        (append keeps the version) and FDMI delete (recreate resets it)
        both punch through the version key."""
        with self._cache_lock:
            for key in [k for k in self._partial_cache if k[1] == oid]:
                del self._partial_cache[key]

    def _cache_on_fdmi(self, event: str, oid: str, info: Dict):
        if event == "delete":
            self._cache_invalidate(oid)

    def _cache_key(self, frag_key: str, oid: str
                   ) -> Optional[Tuple[str, str, int]]:
        try:
            return (frag_key, oid, self.clovis.store.meta(oid).version)
        except KeyError:
            return None

    def _cache_probe(self, frag_key: str, oid: str) -> bool:
        key = self._cache_key(frag_key, oid)
        if key is None:
            return False
        with self._cache_lock:
            return key in self._partial_cache

    def _cache_get(self, frag_key: str, oid: str):
        key = self._cache_key(frag_key, oid)
        if key is None:
            return None
        with self._cache_lock:
            val = self._partial_cache.get(key)
            if val is not None:
                self._partial_cache.move_to_end(key)
            return val

    def _cache_put(self, frag_key: str, oid: str, partial, version: int):
        """Insert under the version captured *before* the data was read
        (versions are monotonic, so the entry can never claim a newer
        version than the bytes it was computed from — a concurrent
        write just strands the entry at the old, unreachable key)."""
        if version < 0 or partial is None:
            return
        key = (frag_key, oid, version)
        with self._cache_lock:
            self._partial_cache[key] = partial
            self._partial_cache.move_to_end(key)
            while len(self._partial_cache) > self._partial_cache_size:
                self._partial_cache.popitem(last=False)

    # -- fragment shipping hook (serving engines override) -------------

    def _ship_fragment(self, name: str, frag_key: str, oid: str,
                       stats: Optional[QueryStats] = None,
                       columns: Optional[Tuple[int, ...]] = None):
        """Ship one compiled fragment at one object.  ``columns``
        non-None routes through the shipper's pruned columnar read
        (ranged block fetches of just those columns).  The serving
        mixin overrides this with cross-query single-flight dedup; the
        base engine just ships."""
        if columns is not None:
            return self.shipper.ship_columns(name, oid, columns)
        return self.shipper.ship(name, oid)

    def _observe_selectivity(self, frag_key: str, oid: str, partial):
        """Feed the selectivity a shipped fragment actually delivered
        back into the stats catalog (rows-shaped partials only — the
        row count is the signal the ship-vs-fetch estimate hinges on)."""
        if not (isinstance(partial, tuple) and len(partial) == 2
                and partial[0] == "rows"):
            return
        st = self.stats.get(oid)
        if st is None or st.rows <= 0:
            return
        rows_out = np.asarray(partial[1]).shape[0]
        self.stats.observe_selectivity(frag_key, oid, rows_out / st.rows)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def run(self, ds: Dataset) -> QueryResult:
        t0 = time.perf_counter()
        stats = QueryStats(pushdown=self._can_push(ds))
        if isinstance(ds.source, LiveStreamSource):
            raise ValueError(
                "dataset reads a live StreamContext — an unbounded flow "
                "has no batch result; execute it with run_continuous() "
                "(incremental watermarked windows), or drain through a "
                "StreamTap for a batch query")
        if isinstance(ds.source, JoinSource):
            value = self._run_join(ds, stats)
        elif isinstance(ds.source, StreamSource):
            plan = optimize(ds.ops, pushdown=False)
            stats.plan = plan.describe()
            partials = self._run_stream(ds, stats)
            value = merge_partials(plan, partials, self.kcfg)
        else:
            pin = self._pin_snapshot(ds.source.container)
            try:
                if pin is not None:
                    snap = pin[1]
                    stats.snapshot_version = snap.version
                    listing = snap.oids
                else:
                    listing = self.clovis.container(ds.source.container)
                oids = self._schedule(listing)
                plan = self._make_plan(ds, oids)
                stats.plan_s = time.perf_counter() - t0
                stats.plan = plan.describe()
                t1 = time.perf_counter()
                partials = self._run_container(ds, plan, oids, stats)
                stats.exec_s = time.perf_counter() - t1
                t2 = time.perf_counter()
                value = merge_partials(plan, partials, self.kcfg)
                stats.merge_s = time.perf_counter() - t2
            finally:
                self._unpin_snapshot(pin)
        stats.wall_s = time.perf_counter() - t0
        return QueryResult(value, stats)

    def run_continuous(self, ds: Dataset, window,
                       **kw) -> ContinuousQuery:
        """Execute a live-stream dataset as a continuous query:
        incremental watermarked event-time windows emitting results
        while the stream is still live (docs/streaming.md).

        ``window`` is the window spec — an ``EventWindow`` (tumbling /
        sliding, size / slide / allowed lateness) or a ``SessionWindow``
        (gap windows whose extents are data-defined); remaining
        keywords pass through to ContinuousQuery (``on_result``
        callback, ``max_results`` bounded queue size, ``delta_rows``
        incremental batch size, ``idle_timeout_s``, and
        ``retraction=True`` for speculative emit + late-data
        re-emission on fixed windows).
        Closed-window partials combine through the FunctionShipper
        partial-aggregate registry (scalars) and ``merge_partials``
        (grouped) — the exact merge code batch queries use, so the two
        modes agree by construction."""
        if not isinstance(ds.source, LiveStreamSource):
            raise ValueError(
                "run_continuous needs a live stream source — build the "
                "dataset with from_stream(StreamContext)")
        splan = optimize_streaming(ds.ops)
        with self._lock:
            self._qid += 1
            tag = f"{self._etag}/cq{self._qid}"
        return ContinuousQuery(ds.source.ctx, splan, window,
                               shipper=self.shipper, kcfg=self.kcfg,
                               addb=self.clovis.addb, tag=tag, **kw)

    # -- partition execution -------------------------------------------

    def _run_stream(self, ds: Dataset, stats: QueryStats) -> List[Any]:
        parts = ds.source.tap.partitions()
        out = []
        for sid in sorted(parts):
            arr = parts[sid]
            stats.partitions += 1
            stats.bytes_scanned += arr.nbytes
            stats.bytes_moved += arr.nbytes      # already caller-side
            stats.schedule.append(sid)
            out.append(apply_ops(ds.ops, arr, self.kcfg))
        return out

    def _run_container(self, ds: Dataset, plan: PhysicalPlan,
                       oids: List[str], stats: QueryStats) -> List[Any]:
        store = self.clovis.store
        stats.schedule = list(oids)
        stats.partitions = len(oids)
        use_ship = plan.pushdown and bool(plan.frag_spec)
        decisions = plan.decisions or {}
        frag_key = frag_cache_key(plan.frag_spec) if plan.frag_spec else ""

        with self._lock:
            self._qid += 1
            qtag = f"{self._etag}/q{self._qid}"
        frag_name = f"{qtag}/frag"
        frag_stats_name = f"{qtag}/frag+stats"
        if use_ship:
            self.shipper.register(
                frag_name, compile_fragment(plan.frag_spec, self.kcfg))
            self.shipper.register(
                frag_stats_name,
                compile_fragment(plan.frag_spec, self.kcfg,
                                 collect_stats=True))

        if decisions:
            stats.query_tag = qtag
            for oid, d in decisions.items():
                self.clovis.addb.record_decision(qtag, oid, d.mode,
                                                 d.est_moved, d.est_s)

        # never stage a CACHED partition: its plan needs zero I/O, and
        # migration would bump the version and defeat the cache hit
        stageable = [o for o in oids
                     if o not in decisions or decisions[o].mode != CACHED]
        staged = (self._stage_cold(stageable, stats)
                  if self.prefetch_cold else {})
        errors: List[str] = []
        lock = threading.Lock()
        prune_ok = use_ship and hasattr(self.clovis, "read_columns")

        # double-buffered block streaming (fetch-mode partitions): a
        # side pool reads the next partition's bytes while the current
        # one's kernel runs, keeping the store's read path and the
        # caller's compute overlapped instead of strictly alternating
        if use_ship:
            fetch_oids = [o for o in oids if o in decisions
                          and decisions[o].mode == FETCH]
        else:
            fetch_oids = [o for o in oids
                          if decisions.get(o) is None
                          or decisions[o].mode != CACHED]
        dbl: Dict[str, Any] = {}
        dbl_lock = threading.Lock()
        dbl_iter = iter(fetch_oids)
        dbl_pool = (ThreadPoolExecutor(
                        max_workers=min(len(fetch_oids),
                                        self.max_workers + 1),
                        thread_name_prefix="sage-dblbuf")
                    if len(fetch_oids) > 1 else None)

        def _dbl_read(o: str):
            fut = staged.get(o)
            if fut is not None:
                fut.result()             # promotion finished (or failed)
            try:
                ver = store.meta(o).version
            except KeyError:
                ver = -1
            return ver, self._fetch(o)

        def _dbl_advance():
            """Submit the next not-yet-read fetch partition (one per
            consumed buffer, so at most depth reads are in flight)."""
            if dbl_pool is None:
                return
            with dbl_lock:
                for nxt in dbl_iter:
                    dbl[nxt] = dbl_pool.submit(_dbl_read, nxt)
                    return

        if dbl_pool is not None:
            for _ in range(self.max_workers + 1):
                _dbl_advance()

        def task(oid: str):
            d = decisions.get(oid)
            mode = d.mode if d is not None else (SHIP if use_ship else FETCH)
            if mode == CACHED:
                partial = self._cache_get(frag_key, oid)
                if partial is not None:
                    with lock:
                        stats.cache_hits += 1
                        stats.decisions[oid] = CACHED
                    if plan.local_ops:
                        partial = apply_ops(plan.local_ops, partial[1],
                                            self.kcfg)
                    return partial
                mode = SHIP if use_ship else FETCH   # raced invalidation
            fut = staged.get(oid)
            if fut is not None:
                fut.result()                 # promotion finished (or failed)
            size = store.read_size(oid)
            pruned = pipelined = False
            if mode == SHIP and use_ship:
                name = frag_name
                if self.cost_based and not self.stats.fresh(oid):
                    name = frag_stats_name   # piggyback a stats refresh
                cols = None
                if prune_ok and name is frag_name:
                    # (the stats piggyback summarizes whole rows, so it
                    # always reads the full object)
                    try:
                        attrs = store.meta(oid).attrs
                    except KeyError:
                        attrs = {}
                    cols = prunable_columns(plan.frag_spec, attrs)
                    if cols is not None:
                        from repro.core.columnar import column_nbytes
                        size = column_nbytes(attrs, cols)
                        pruned = True
                res = self._ship_fragment(name, frag_key, oid, stats,
                                          columns=cols)
                if not res.ok:
                    with lock:
                        errors.append(f"{oid}: {res.error}")
                    return None
                partial = res.value
                moved = _nbytes(partial)
                if isinstance(partial, dict) and STATS_KEY in partial:
                    partial = partial["partial"]
                self._cache_put(frag_key, oid, partial, res.version)
                self._observe_selectivity(frag_key, oid, partial)
                if plan.local_ops:
                    # the fragment never aggregates when a caller tail
                    # exists, so its output is always rows
                    partial = apply_ops(plan.local_ops, partial[1],
                                        self.kcfg)
            else:
                # whole chain runs caller-side on the fetched object
                fut2 = None
                if dbl_pool is not None:
                    with dbl_lock:
                        fut2 = dbl.pop(oid, None)
                if fut2 is not None:
                    _dbl_advance()       # next fetch overlaps our kernel
                    version, arr = fut2.result()
                    pipelined = True
                else:
                    try:
                        version = store.meta(oid).version
                    except KeyError:
                        version = -1
                    arr = self._fetch(oid)
                moved = arr.nbytes
                partial = apply_ops(ds.ops, arr, self.kcfg)
                if use_ship and not plan.local_ops:
                    # no caller tail: the full-chain result IS the
                    # fragment partial, so it is cacheable
                    self._cache_put(frag_key, oid, partial, version)
            with lock:
                stats.bytes_scanned += size
                stats.bytes_moved += moved
                stats.decisions[oid] = mode
                if pruned:
                    stats.pruned_reads += 1
                if pipelined:
                    stats.double_buffered += 1
            return partial

        try:
            with ThreadPoolExecutor(max_workers=self.max_workers,
                                    thread_name_prefix="sage-analytics"
                                    ) as pool:
                partials = list(pool.map(task, oids))
        finally:
            if dbl_pool is not None:
                dbl_pool.shutdown(wait=False)
            if use_ship:
                self.shipper.unregister(frag_name)
                self.shipper.unregister(frag_stats_name)
        if errors:
            raise AnalyticsError("; ".join(errors))
        return partials

    def _fetch(self, oid: str) -> np.ndarray:
        """Fetch path: the whole object crosses to the caller (same
        materialization rule the storage-side shipper uses)."""
        return self.clovis.materialize(oid)

    # -- tier/heat-aware scheduling ------------------------------------

    def _heat(self, oids: List[str]) -> Dict[str, float]:
        return self._policy_map(oids, "heat_map")

    def _schedule(self, oids: List[str]) -> List[str]:
        """Hot/fast-tier partitions first: they run while cold ones are
        still being promoted (or are simply slower to read)."""
        store = self.clovis.store
        heat = self._heat(oids)
        return sorted(oids, key=lambda o: (
            _TIER_RANK[store.meta(o).layout.tier], -heat.get(o, 0.0), o))

    def _stage_cold(self, oids: List[str], stats: QueryStats) -> Dict:
        """Kick slow-tier partitions' promotion onto a background pool so
        migration overlaps execution of the hot partitions (which sort
        first and drain the task queue while these stage)."""
        store = self.clovis.store
        cold = [o for o in oids
                if store.meta(o).layout.tier in _SLOW_TIERS]
        if not cold:
            return {}
        pool = ThreadPoolExecutor(max_workers=2,
                                  thread_name_prefix="sage-stage")

        def promote(oid: str):
            try:
                meta = store.meta(oid)
                store.migrate(oid, lay.Layout(meta.layout.kind, T2_FLASH,
                                              meta.layout.width))
                with self._lock:
                    stats.prefetched += 1
            except Exception:
                pass                      # staging is advisory

        futs = {oid: pool.submit(promote, oid) for oid in cold}
        pool.shutdown(wait=False)
        return futs

    # -- join ----------------------------------------------------------

    def _run_join(self, ds: Dataset, stats: QueryStats):
        src: JoinSource = ds.source
        lres = self.run(src.left)
        rres = self.run(src.right)
        for side in (lres, rres):
            stats.partitions += side.stats.partitions
            stats.bytes_scanned += side.stats.bytes_scanned
            stats.bytes_moved += side.stats.bytes_moved
            stats.cache_hits += side.stats.cache_hits
            stats.schedule.extend(side.stats.schedule)
            stats.decisions.update(side.stats.decisions)
        lrows, rrows = np.atleast_2d(lres.value), np.atleast_2d(rres.value)
        joined = self._join_rows(lrows, rrows, src.on, stats)
        if not ds.ops:
            return joined
        plan = optimize(ds.ops, pushdown=False)
        stats.plan = plan.describe()
        return merge_partials(plan, [apply_ops(ds.ops, joined, self.kcfg)],
                              self.kcfg)

    def _join_rows(self, lrows, rrows, on: Tuple[int, int],
                   stats: QueryStats) -> np.ndarray:
        if (lrows.size and rrows.size
                and lrows.nbytes + rrows.nbytes > self.spill_bytes):
            return self._grace_join(lrows, rrows, on, stats)
        return _hash_join(lrows, rrows, on)

    def _grace_join(self, lrows, rrows, on: Tuple[int, int],
                    stats: QueryStats) -> np.ndarray:
        """Grace hash join: both sides hash-partition into spill objects
        (tier picked by RTHMS recommend_tier), then join bucket-wise so
        peak memory is ~1/P of the input."""
        store = self.clovis.store
        nb = 8
        with self._lock:
            self._qid += 1
            qtag = f"{self.spill_container}/q{self._qid}"
        spilled: List[str] = []
        buckets: Dict[Tuple[str, int], str] = {}
        for name, rows, kc in (("l", lrows, on[0]), ("r", rrows, on[1])):
            keys = rows[:, kc].astype(np.int64) % nb
            for b in range(nb):
                sub = rows[keys == b]
                if not sub.shape[0]:
                    continue
                tier = recommend_tier(store, size_bytes=sub.nbytes,
                                      read_fraction=0.5, random_access=False)
                oid = f"{qtag}/{name}{b}"
                self.clovis.put_array(oid, sub,
                                      container=self.spill_container,
                                      layout=lay.Layout(lay.STRIPED, tier, 2))
                buckets[(name, b)] = oid
                spilled.append(oid)
                stats.spilled_bytes += sub.nbytes
        try:
            outs = []
            for b in range(nb):
                lo = buckets.get(("l", b))
                ro = buckets.get(("r", b))
                if lo is None or ro is None:
                    continue
                outs.append(_hash_join(self.clovis.get_array(lo),
                                       self.clovis.get_array(ro), on))
            outs = [o for o in outs if o.shape[0]]
            if not outs:
                return np.zeros((0, lrows.shape[1] + rrows.shape[1]))
            return np.vstack(outs)
        finally:
            for oid in spilled:
                try:
                    self.clovis.delete(oid)
                except KeyError:
                    pass

    def close(self):
        if self._own_stats:
            # engine-private catalog: unhook it everywhere so
            # short-lived engines don't accrete hooks on a long-lived
            # stack.  A shared catalog's shipper observer stays: other
            # engines on the same shipper still harvest through it, and
            # the catalog outlives its engines by design.
            self.shipper.remove_observer(self.stats._on_ship)
            self.stats.detach()
        self.clovis.store.unregister_write_hook(self._cache_invalidate)
        self.clovis.store.fdmi_unregister(self._cache_on_fdmi)
        if self._own_shipper:
            self.shipper.shutdown()


def _hash_join(lrows: np.ndarray, rrows: np.ndarray,
               on: Tuple[int, int]) -> np.ndarray:
    """In-memory inner equi-join; output rows are left cols ++ right
    cols, ordered by left row then right row (deterministic)."""
    lc, rc = on
    ncols = lrows.shape[1] + rrows.shape[1]
    if not lrows.size or not rrows.size:
        return np.zeros((0, ncols))
    rk = rrows[:, rc].astype(np.int64)
    index: Dict[int, List[int]] = {}
    for j, k in enumerate(rk):
        index.setdefault(int(k), []).append(j)
    li, ri = [], []
    for i, k in enumerate(lrows[:, lc].astype(np.int64)):
        for j in index.get(int(k), ()):
            li.append(i)
            ri.append(j)
    if not li:
        return np.zeros((0, ncols))
    return np.hstack([lrows[li], rrows[ri]])
