"""Column expression DSL — the serialisable predicate/projection
language of SAGE's function-shipping contract (paper §3.2.1: shipped
computations are descriptions, not code).

Pushdown must not ship Python closures: a fragment that runs *at the
store* is described entirely by a JSON-able spec so the storage-side
executor can rebuild it without trusting caller bytecode (and so the
plan is printable, and the cost model can estimate predicate
selectivity by walking the same spec).  ``col(i)`` and ``lit(v)`` build
small ASTs with numpy operator overloading:

    pred = (col(1) > 0.5) & (col(0) % 2 == 0)
    keep = pred(rows)          # (n,) bool over a (n, ncols) array

Boolean composition uses ``&``/``|``/``~`` (like numpy/pandas, since
``and``/``or`` cannot be overloaded).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import numpy as np

_BINOPS: Dict[str, Callable[[Any, Any], Any]] = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "&": lambda a, b: a & b,
    "|": lambda a, b: a | b,
}


class Expr:
    """Base expression node; evaluates against a (rows, ncols) array."""

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def to_spec(self) -> Dict:
        raise NotImplementedError

    def columns(self) -> set:
        """Column indices this expression reads (stats collection and
        selectivity estimation introspect the AST through this)."""
        return set()

    # -- operator overloading builds the AST --

    def _bin(self, op: str, other, flip: bool = False) -> "Expr":
        other = other if isinstance(other, Expr) else Lit(other)
        return BinOp(op, other, self) if flip else BinOp(op, self, other)

    def __add__(self, o):  return self._bin("+", o)          # noqa: E704
    def __radd__(self, o): return self._bin("+", o, True)    # noqa: E704
    def __sub__(self, o):  return self._bin("-", o)          # noqa: E704
    def __rsub__(self, o): return self._bin("-", o, True)    # noqa: E704
    def __mul__(self, o):  return self._bin("*", o)          # noqa: E704
    def __rmul__(self, o): return self._bin("*", o, True)    # noqa: E704
    def __truediv__(self, o):  return self._bin("/", o)      # noqa: E704
    def __rtruediv__(self, o): return self._bin("/", o, True)  # noqa: E704
    def __mod__(self, o):  return self._bin("%", o)          # noqa: E704
    def __gt__(self, o):   return self._bin(">", o)          # noqa: E704
    def __ge__(self, o):   return self._bin(">=", o)         # noqa: E704
    def __lt__(self, o):   return self._bin("<", o)          # noqa: E704
    def __le__(self, o):   return self._bin("<=", o)         # noqa: E704
    def __eq__(self, o):   return self._bin("==", o)         # noqa: E704
    def __ne__(self, o):   return self._bin("!=", o)         # noqa: E704
    def __and__(self, o):  return self._bin("&", o)          # noqa: E704
    def __or__(self, o):   return self._bin("|", o)          # noqa: E704
    def __invert__(self):  return Not(self)                  # noqa: E704

    __hash__ = None


class Col(Expr):
    def __init__(self, i: int):
        self.i = int(i)

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        return rows[:, self.i]

    def to_spec(self) -> Dict:
        return {"t": "col", "i": self.i}

    def columns(self) -> set:
        return {self.i}

    def __repr__(self):
        return f"col({self.i})"


class Lit(Expr):
    def __init__(self, v):
        self.v = v

    def __call__(self, rows: np.ndarray):
        return self.v

    def to_spec(self) -> Dict:
        # numpy scalars (e.g. arr.max()) coerce to plain Python so the
        # spec stays JSON-able and selectivity-estimable
        v = self.v.item() if isinstance(self.v, np.generic) else self.v
        return {"t": "lit", "v": v}

    def __repr__(self):
        return repr(self.v)


class BinOp(Expr):
    def __init__(self, op: str, l: Expr, r: Expr):
        if op not in _BINOPS:
            raise ValueError(f"unknown operator {op!r}")
        self.op, self.l, self.r = op, l, r

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        return _BINOPS[self.op](self.l(rows), self.r(rows))

    def to_spec(self) -> Dict:
        return {"t": "bin", "op": self.op, "l": self.l.to_spec(),
                "r": self.r.to_spec()}

    def columns(self) -> set:
        return self.l.columns() | self.r.columns()

    def __repr__(self):
        return f"({self.l!r} {self.op} {self.r!r})"


class Not(Expr):
    def __init__(self, e: Expr):
        self.e = e

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        return ~self.e(rows)

    def to_spec(self) -> Dict:
        return {"t": "not", "e": self.e.to_spec()}

    def columns(self) -> set:
        return self.e.columns()

    def __repr__(self):
        return f"~{self.e!r}"


def col(i: int) -> Col:
    """Reference column ``i`` of the dataset's row array."""
    return Col(i)


def lit(v) -> Lit:
    return Lit(v)


def from_spec(spec: Dict) -> Expr:
    """Rebuild an Expr from its JSON-able spec (the storage-side half of
    pushdown: fragments travel as specs, never as closures)."""
    t = spec["t"]
    if t == "col":
        return Col(spec["i"])
    if t == "lit":
        return Lit(spec["v"])
    if t == "bin":
        return BinOp(spec["op"], from_spec(spec["l"]), from_spec(spec["r"]))
    if t == "not":
        return Not(from_spec(spec["e"]))
    raise ValueError(f"bad expr spec {spec!r}")


def as_expr(x) -> Expr:
    """Coerce a column index or Expr into an Expr."""
    if isinstance(x, Expr):
        return x
    if isinstance(x, (int, np.integer)):
        return Col(int(x))
    raise TypeError(f"expected column index or Expr, got {type(x).__name__}")
