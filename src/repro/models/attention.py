"""Grouped-query attention: global / sliding-window / cross, with KV caches.

Three execution paths with identical semantics:
  * ``attend_dense``    — materialised scores; smoke tests & short sequences.
  * ``attend_chunked``  — XLA online-softmax over KV chunks; long sequences
                          (bounded memory, same FLOPs — the portable
                          "flash attention in XLA" used by the dry-run).
  * Pallas flash kernel — ``repro.kernels.ops.flash_attention`` on TPU.

Caches are fixed-size ring buffers: ``k/v`` of length ``W`` plus a ``pos``
vector holding the absolute position stored in each slot (-1 = empty).  For
global attention W = max_len; for sliding-window layers W = window, which is
what makes recurrentgemma's 500k decode O(window) instead of O(seq).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import apply_rope, dense_init, shard_heads, softcap

NEG_INF = -2.0e38  # fp32-safe mask value


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def head_maps(cfg: ModelConfig):
    """TP head-padding maps: (q_slot -> real q idx or -1, kv_slot -> real kv).

    See configs.base.apply_tp_padding: padded q slots are laid out so that
    slot j's padded KV group (j // (n_heads/n_kv)) replicates the original
    head's real KV group — function-preserving GQA KV replication.
    """
    h, kv = cfg.n_heads, cfg.n_kv_heads
    hr, kvr = cfg.n_heads_real, cfg.n_kv_heads_real
    if h == hr and kv == kvr:
        return list(range(h)), list(range(kv))
    if kvr == kv:
        # only q was padded (MoE-style trailing pad)
        return [i if i < hr else -1 for i in range(h)], list(range(kv))
    if kvr == hr:
        # MHA joint pad: identity prefix
        qmap = [i if i < hr else -1 for i in range(h)]
        kvmap = [i if i < kvr else 0 for i in range(kv)]
        return qmap, kvmap
    rep = kv // kvr                       # kv replication factor
    g_real = hr // kvr                    # real q heads per kv group
    slots_per_kv_group = h // kvr         # = rep * padded group
    qmap = [-1] * h
    for k in range(kvr):
        for i0 in range(g_real):
            qmap[k * slots_per_kv_group + i0] = k * g_real + i0
    kvmap = [c // rep for c in range(kv)]
    return qmap, kvmap


def _place_heads(w_real: jax.Array, qmap, axis: int) -> jax.Array:
    """Scatter real head slices into the padded layout (zeros elsewhere)."""
    parts = []
    for j in qmap:
        if j < 0:
            parts.append(jnp.zeros_like(jnp.take(w_real, 0, axis=axis)))
        else:
            parts.append(jnp.take(w_real, j, axis=axis))
    return jnp.stack(parts, axis=axis)


def init_attention(key, cfg: ModelConfig, *, cross: bool = False,
                   dtype=jnp.float32) -> Dict:
    """QKVO projections (+ optional biases, cross-attn gate/norms)."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hr, kvr = cfg.n_heads_real, cfg.n_kv_heads_real
    ks = common.split_keys(key, 8)
    wq = dense_init(ks[0], (d, hr, hd), dtype=dtype)
    wk = dense_init(ks[1], (d, kvr, hd), dtype=dtype)
    wv = dense_init(ks[2], (d, kvr, hd), dtype=dtype)
    wo = dense_init(ks[3], (hr, hd, d), in_axis=1, dtype=dtype)
    if (h, kv) != (hr, kvr):
        qmap, kvmap = head_maps(cfg)
        wq = _place_heads(wq, qmap, axis=1)
        wo = _place_heads(wo, qmap, axis=0)
        wk = _place_heads(wk, kvmap, axis=1)
        wv = _place_heads(wv, kvmap, axis=1)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cross:
        # llama-3.2-vision style gated cross attention: rmsnorm on q/k,
        # tanh gates on attn output (the MLP gate lives in the block).
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
        p["gate"] = jnp.zeros((), dtype)
    return p


# --------------------------------------------------------------------------
# Core attention math
# --------------------------------------------------------------------------

def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, kv, hd) -> (b, s, h, hd) by repeating each kv group."""
    b, s, kv, hd = k.shape
    if kv == n_heads:
        return k
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2)


def _scale(cfg: ModelConfig) -> float:
    if cfg.query_scale is not None:
        return cfg.query_scale
    return 1.0 / math.sqrt(cfg.head_dim)


def attend_dense(q: jax.Array, k: jax.Array, v: jax.Array,
                 mask: jax.Array, cfg: ModelConfig) -> jax.Array:
    """q: (b, sq, h, hd); k/v: (b, sk, kv, hd); mask: (b?, sq, sk) bool."""
    k = _expand_kv(k, q.shape[2])
    v = _expand_kv(v, q.shape[2])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits * _scale(cfg)
    logits = softcap(logits, cfg.attn_softcap)
    if mask.ndim == 3:
        mask = mask[:, None]          # (b, 1, sq, sk)
    else:
        mask = mask[None, None]       # (1, 1, sq, sk)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, k_pos: jax.Array, cfg: ModelConfig,
                   *, causal: bool, window: int, chunk: int = 1024) -> jax.Array:
    """Online-softmax attention over KV chunks (memory O(sq * chunk)).

    q_pos: (sq,) absolute positions of queries; k_pos: (sk,) of keys
    (-1 marks an empty cache slot).  Semantics identical to attend_dense
    with mask built from positions.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    vd = v.shape[-1]            # may differ from hd (MLA: v_head_dim)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = _scale(cfg)

    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)

    k = k.reshape(b, n_chunks, chunk, h, hd)
    v = v.reshape(b, n_chunks, chunk, h, vd)
    k_pos = k_pos.reshape(n_chunks, chunk)

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        kc, vc, kp = inputs              # (b, chunk, h, hd), (chunk,)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, cfg.attn_softcap)
        valid = kp[None, :] >= 0
        if causal:
            valid = valid & (kp[None, :] <= q_pos[:, None])
        if window > 0:
            valid = valid & (kp[None, :] > q_pos[:, None] - window)
        logits = jnp.where(valid[None, None], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)                     # (b, h, q)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), k_pos))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (b, sq, h, hd)


# --------------------------------------------------------------------------
# Layer-level forward (full sequence: train / prefill)
# --------------------------------------------------------------------------

# sequences at or above this length use the chunked path under jit
CHUNKED_THRESHOLD = 8192

# perf knob (hillclimb): force the online-softmax chunked path for ALL
# sequence lengths (never materialise (sq, sk) score tensors in HBM) —
# the XLA-portable analogue of running the Pallas flash kernel.
import threading as _threading

_ATTN_IMPL = _threading.local()


def set_attention_impl(impl: str):
    """'auto' (dense below CHUNKED_THRESHOLD) or 'chunked' (always)."""
    _ATTN_IMPL.impl = impl


def _use_chunked(s: int) -> bool:
    impl = getattr(_ATTN_IMPL, "impl", "auto")
    return impl == "chunked" or s >= CHUNKED_THRESHOLD


def _project_qkv(p: Dict, x: jax.Array, cfg: ModelConfig,
                 kv_src: Optional[jax.Array] = None):
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return shard_heads(q), k, v


def self_attention(p: Dict, x: jax.Array, positions: jax.Array,
                   cfg: ModelConfig, *, window: int = 0,
                   use_rope: bool = True) -> jax.Array:
    """Causal self attention over a full sequence.  x: (b, s, d)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

    pos = positions[0] if positions.ndim == 2 else positions
    if _use_chunked(s):
        out = attend_chunked(q, k, v, pos, pos, cfg, causal=True,
                             window=window)
    else:
        mask = pos[:, None] >= pos[None, :]
        if window > 0:
            mask &= pos[:, None] - pos[None, :] < window
        out = attend_dense(q, k, v, mask, cfg)
    out = shard_heads(out)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_attention(p: Dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig, *, gated: bool = False,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None
                    ) -> jax.Array:
    """Encoder-decoder / vision cross attention (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if kv_override is not None:
        k, v = kv_override
    else:
        k, v = cross_kv(p, memory, cfg, x.dtype)
    if gated:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = shard_heads(q)
    mask = jnp.ones((x.shape[1], k.shape[1]), bool)
    out = attend_dense(q, k, v, mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if gated:
        out = jnp.tanh(p["gate"].astype(x.dtype)) * out
    return out


def cross_kv(p: Dict, memory: jax.Array, cfg: ModelConfig, dtype):
    """Precompute cross-attn K/V from encoder memory (cached at prefill)."""
    k = jnp.einsum("bsd,dhk->bshk", memory.astype(dtype), p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory.astype(dtype), p["wv"].astype(dtype))
    if "bk" in p:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return k, v


# --------------------------------------------------------------------------
# KV cache (ring buffer) — prefill & decode
# --------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.local_window:
        return min(cfg.local_window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
               dtype=jnp.bfloat16) -> Dict:
    w = cache_len(cfg, kind, max_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, w, kv, hd), dtype),
        "v": jnp.zeros((batch, w, kv, hd), dtype),
        "pos": jnp.full((w,), -1, jnp.int32),
    }


def prefill_attention(p: Dict, x: jax.Array, positions: jax.Array,
                      cfg: ModelConfig, cache: Dict, *, window: int = 0
                      ) -> Tuple[jax.Array, Dict]:
    """Full-sequence attention that also fills the ring cache."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

    pos = positions[0] if positions.ndim == 2 else positions
    if _use_chunked(s):
        out = attend_chunked(q, k, v, pos, pos, cfg, causal=True, window=window)
    else:
        mask = pos[:, None] >= pos[None, :]
        if window > 0:
            mask &= pos[:, None] - pos[None, :] < window
        out = attend_dense(q, k, v, mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", shard_heads(out), p["wo"].astype(x.dtype))

    w = cache["k"].shape[1]
    if s >= w:
        # keep the last w entries, laid out by the ring invariant
        # slot(p) = p % w so later decode writes evict the oldest entry
        shift = (s - w) % w
        cache = {
            "k": jnp.roll(k[:, s - w:], shift, axis=1).astype(cache["k"].dtype),
            "v": jnp.roll(v[:, s - w:], shift, axis=1).astype(cache["v"].dtype),
            "pos": jnp.roll(pos[s - w:], shift).astype(jnp.int32),
        }
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            "pos": jax.lax.dynamic_update_slice(
                cache["pos"], pos.astype(jnp.int32), (0,)),
        }
    return out, cache


def decode_attention(p: Dict, x: jax.Array, position: jax.Array,
                     cfg: ModelConfig, cache: Dict, *, window: int = 0
                     ) -> Tuple[jax.Array, Dict]:
    """Single-token decode step against the ring cache.

    x: (b, 1, d); position: scalar int32 (same step for the whole batch —
    the serving model runs synchronous batched decode).
    """
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.pos_embedding == "rope":
        posb = jnp.full((1,), 0, jnp.int32) + position
        q = apply_rope(q, posb[None, :], cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, posb[None, :], cfg.rope_theta, cfg.rope_fraction)

    w = cache["k"].shape[1]
    slot = position % w
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    pos_cache = jax.lax.dynamic_update_slice(
        cache["pos"], position[None].astype(jnp.int32), (slot,))

    valid = (pos_cache >= 0) & (pos_cache <= position)
    if window > 0:
        valid &= pos_cache > position - window
    mask = jnp.broadcast_to(valid[None, :], (1, w))       # (sq=1, sk=w)
    out = attend_dense(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                       mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", shard_heads(out), p["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}
