"""chatglm3-6b — dense, 2d RoPE (half-dim), GQA kv=2, QKV bias.

[arXiv:2406.12793; hf]
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    qkv_bias=True,
    act="silu",
    rope_fraction=0.5,   # ChatGLM's 2d rope: rotate only half the head dims
    attn_pattern=(GLOBAL_ATTN,),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)
