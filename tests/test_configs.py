"""Guard the assigned architecture numbers (as transcribed from the task)
and config-system invariants."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.configs.base import apply_tp_padding, shape_applicable

# (layers, d_model, heads, kv, d_ff, vocab) per the assignment
ASSIGNED = {
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert (cfg.d_ff == ff or cfg.d_expert == ff)
    assert cfg.vocab_size == v


def test_arch_specials():
    assert get_config("qwen2.5-32b").qkv_bias
    g = get_config("gemma2-27b")
    assert g.attn_softcap == 50.0 and g.final_softcap == 30.0
    assert g.attn_pattern == ("local", "global")
    assert get_config("chatglm3-6b").rope_fraction == 0.5
    q = get_config("qwen2-moe-a2.7b")
    assert q.n_experts == 60 and q.top_k == 4 and q.n_shared_experts == 4
    ds = get_config("deepseek-v3-671b")
    assert ds.use_mla and ds.n_experts == 256 and ds.top_k == 8
    assert ds.kv_lora_rank == 512 and ds.mtp_depth == 1
    w = get_config("whisper-large-v3")
    assert w.is_encoder_decoder and w.encoder_seq == 1500
    lv = get_config("llama-3.2-vision-90b")
    assert lv.cross_attn_period == 5 and lv.n_layers % 5 == 0
    rg = get_config("recurrentgemma-9b")
    assert rg.attn_pattern == ("rglru", "rglru", "local")
    m = get_config("mamba2-130m")
    assert m.ssm_state == 128 and m.attn_pattern == ("ssd",)


def test_param_counts_in_expected_range():
    """Sanity: analytic param counts land near the advertised sizes."""
    from repro.models.model import count_params_analytic

    expect = {"qwen2.5-32b": (28e9, 38e9),
              "internlm2-20b": (17e9, 24e9),
              "gemma2-27b": (22e9, 32e9),
              "chatglm3-6b": (5e9, 8e9),
              "deepseek-v3-671b": (600e9, 720e9),
              "mamba2-130m": (0.10e9, 0.16e9),
              "recurrentgemma-9b": (7e9, 12e9)}
    for arch, (lo, hi) in expect.items():
        n = count_params_analytic(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    from repro.models.model import count_params_analytic

    ds = get_config("deepseek-v3-671b")
    active = count_params_analytic(ds, active_only=True)
    total = count_params_analytic(ds)
    assert active < 0.1 * total          # 37B active of 671B
    assert 25e9 < active < 50e9


@pytest.mark.parametrize("tp", [4, 8, 16])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_tp_padding_divisibility(arch, tp):
    cfg = apply_tp_padding(get_config(arch), tp)
    if cfg.n_heads:
        assert cfg.n_heads % tp == 0
        assert cfg.n_kv_heads % tp == 0 or cfg.use_mla
    assert cfg.vocab_size % tp == 0
    # padding preserves real dims
    assert cfg.n_heads_real == get_config(arch).n_heads or cfg.n_heads == get_config(arch).n_heads
    assert cfg.vocab_real == get_config(arch).vocab_size


def test_shape_skips_match_design():
    skips = []
    for arch in ARCH_IDS:
        ok, _ = shape_applicable(arch, SHAPES["long_500k"], get_config(arch))
        if not ok:
            skips.append(arch)
    assert "mamba2-130m" not in skips
    assert "recurrentgemma-9b" not in skips
    assert len(skips) == 8
