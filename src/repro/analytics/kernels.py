"""Aggregation hot-path kernels: segmented group-by reduce, windowed
reductions, histogram — the TPU-era stand-ins for SAGE's in-storage
compute primitives (paper §4.1: the reductions its Data Analytics
layer runs next to the data).

Layout follows the percipience heat-scan idiom (percipience/heat.py):
inputs are padded to f32/int32 tile multiples (8, 128), the grid is
parallel over output blocks, and CPU containers run the same kernel body
with ``interpret=True``.  A pure-numpy reference implementation backs
every kernel for correctness checks and as the no-JAX fallback.

Segmented reduce: values live in a (rows, 128)-lane layout; each grid
step owns a 128-segment block and folds every row in with a lane-iota
membership mask — a (128 values x 128 segments) compare + masked reduce
per row, all VPU work.  Integer inputs reduce in int32 so integer
aggregates are *exact* (no f32 rounding), matching the numpy reference
bit-for-bit.

Windowed reduce: values arranged (window, n_windows) — window axis on
sublanes, windows on lanes — one column reduce per 128-window block,
the same shape trick the heat kernel uses for (hist, nobj).
"""
from __future__ import annotations

import functools
import json
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analytics.exprs import _BINOPS

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

OPS = ("sum", "count", "min", "max")
_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_mode(interpret: bool = False) -> str:
    """How a kernel call will actually execute: ``pallas-tpu`` (compiled
    Mosaic), ``xla-jit`` (compiled XLA fallback — the honest CPU path),
    or ``interpret`` (Pallas interpreter; correctness only, never
    timing).  Benchmarks label every number with this."""
    if interpret:
        return "interpret"
    return "pallas-tpu" if _on_tpu() else "xla-jit"


def _identity(op: str, dtype) -> float:
    if op in ("sum", "count"):
        return 0
    big = np.iinfo(dtype).max if np.issubdtype(dtype, np.integer) \
        else np.inf
    return big if op == "min" else -big


# ---------------------------------------------------------------------------
# expression-spec evaluation (shared by the fused kernel + XLA fallback)
# ---------------------------------------------------------------------------

def eval_spec(spec: Dict, getcol):
    """Evaluate a serialised expression spec (exprs.to_spec) against
    ``getcol(i) -> array``.  The operator table is generic, so the same
    walker runs on numpy arrays (host reference), jnp arrays (XLA
    fallback) and Pallas block values (fused kernel body)."""
    t = spec["t"]
    if t == "col":
        return getcol(spec["i"])
    if t == "lit":
        return spec["v"]
    if t == "bin":
        return _BINOPS[spec["op"]](eval_spec(spec["l"], getcol),
                                   eval_spec(spec["r"], getcol))
    if t == "not":
        return ~eval_spec(spec["e"], getcol)
    raise ValueError(f"bad expr spec {spec!r}")


def spec_columns(spec: Optional[Dict]) -> set:
    """Column indices a spec reads (pruned-scan planning)."""
    if spec is None:
        return set()
    t = spec["t"]
    if t == "col":
        return {spec["i"]}
    if t == "bin":
        return spec_columns(spec["l"]) | spec_columns(spec["r"])
    if t == "not":
        return spec_columns(spec["e"])
    return set()


_CMP_OPS = (">", ">=", "<", "<=", "==", "!=")


def _spec_dtype(spec: Dict, coldt: Dict[int, np.dtype]) -> np.dtype:
    """Result dtype of a spec under numpy promotion — how the unfused
    path's ``expr(rows)`` would come out, so the fused kernel picks the
    identical int32/float32 accumulator."""
    t = spec["t"]
    if t == "col":
        return np.dtype(coldt[spec["i"]])
    if t == "lit":
        return np.asarray(spec["v"]).dtype
    if t == "not":
        return np.dtype(bool)
    if spec["op"] in _CMP_OPS:
        return np.dtype(bool)
    l = _spec_dtype(spec["l"], coldt)
    r = _spec_dtype(spec["r"], coldt)
    if spec["op"] == "/":
        return np.result_type(l, r, np.float32)
    return np.result_type(l, r)


# ---------------------------------------------------------------------------
# segmented group-by reduce
# ---------------------------------------------------------------------------

def _segment_kernel(v_ref, id_ref, out_ref, *, rows: int, op: str,
                    ident):
    """v, id: (rows, 128) value/segment-id lanes; out: (1, 128) — the
    reduced value of each segment in this grid step's 128-segment block."""
    v = v_ref[...]
    ids = id_ref[...]
    base = pl.program_id(0) * _LANES
    segs = base + jax.lax.broadcasted_iota(jnp.int32, (_LANES, _LANES), 1)

    def body(r, acc):                       # acc: (1, 128)
        mask = ids[r][:, None] == segs      # (128 values, 128 segments)
        if op == "count":
            part = jnp.sum(mask.astype(acc.dtype), axis=0)
        elif op == "sum":
            part = jnp.sum(jnp.where(mask, v[r][:, None], 0), axis=0)
        elif op == "min":
            red = jnp.min(jnp.where(mask, v[r][:, None], ident), axis=0)
            return jnp.minimum(acc, red[None, :])
        else:                               # max
            red = jnp.max(jnp.where(mask, v[r][:, None], ident), axis=0)
            return jnp.maximum(acc, red[None, :])
        return acc + part[None, :]

    init = jnp.full_like(out_ref, ident) if op in ("min", "max") \
        else jnp.zeros_like(out_ref)
    out_ref[...] = jax.lax.fori_loop(0, rows, body, init)


@functools.lru_cache(maxsize=512)
def _segment_call(rows: int, n_seg_blocks: int, op: str, dtype_name: str,
                  interpret: bool):
    """Jitted pallas_call for one (tile shape, op, dtype) — cached so
    per-partition calls with a recurring padded shape stop retracing."""
    ident = _identity(op, np.dtype(dtype_name))
    kernel = functools.partial(_segment_kernel, rows=rows, op=op,
                               ident=ident)
    call = pl.pallas_call(
        kernel,
        grid=(n_seg_blocks,),
        in_specs=[
            pl.BlockSpec((rows, _LANES), lambda i: (0, 0)),
            pl.BlockSpec((rows, _LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _LANES), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_seg_blocks * _LANES),
                                       np.dtype(dtype_name)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    return jax.jit(call)


def segment_reduce_pallas(values: jax.Array, seg_ids: jax.Array,
                          n_seg_blocks: int, *, op: str,
                          interpret: bool = False) -> jax.Array:
    """values: (rows, 128) f32/int32; seg_ids: (rows, 128) int32 with -1
    marking padding lanes.  Returns (1, n_seg_blocks * 128) reduced
    values (identity where a segment saw no members)."""
    rows, lanes = values.shape
    assert lanes == _LANES and rows % _SUBLANES == 0
    call = _segment_call(rows, n_seg_blocks, op,
                         np.dtype(values.dtype).name, interpret)
    return call(values, seg_ids)


@functools.lru_cache(maxsize=512)
def _xla_segment_call(op: str, dtype_name: str, n_segments: int):
    """Compiled XLA segmented reduce — the honest non-interpret CPU
    path.  Negative ids route to a dump bucket past the real segments;
    jax.ops.segment_* fill empty segments with the exact op identities
    (0 / iinfo extremes / ±inf), matching ``_identity``."""
    def run(v, ids):
        idx = jnp.where(ids >= 0, ids, n_segments)
        if op == "sum":
            out = jax.ops.segment_sum(v, idx, num_segments=n_segments + 1)
        elif op == "count":
            out = jax.ops.segment_sum(jnp.ones_like(v), idx,
                                      num_segments=n_segments + 1)
        elif op == "min":
            out = jax.ops.segment_min(v, idx, num_segments=n_segments + 1)
        else:
            out = jax.ops.segment_max(v, idx, num_segments=n_segments + 1)
        return out[:n_segments]
    return jax.jit(run)


def segment_reduce(values: np.ndarray, seg_ids: np.ndarray, n_segments: int,
                   *, op: str = "sum",
                   interpret: bool = False) -> np.ndarray:
    """Reduce ``values`` by integer segment id in [0, n_segments).

    Negative ids are dropped.  Integer inputs reduce in int32 (exact);
    everything else in float32.  Returns (n_segments,) with the op
    identity for empty segments.  Off TPU with ``interpret=False`` the
    reduction runs as compiled XLA (``kernel_mode``); ``interpret=True``
    forces the Pallas interpreter (bit-parity with the TPU kernel).
    """
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}")
    v = np.asarray(values).reshape(-1)
    ids = np.asarray(seg_ids, np.int32).reshape(-1)
    if v.shape != ids.shape:
        raise ValueError("values and seg_ids must align")
    dtype = np.int32 if np.issubdtype(v.dtype, np.integer) else np.float32
    if n_segments <= 0 or v.size == 0:
        return np.full((max(n_segments, 0),),
                       _identity(op, np.dtype(dtype)), dtype)
    v = v.astype(dtype)
    ident = _identity(op, np.dtype(dtype))

    n = v.size
    pad = (-n) % _TILE
    if pad:
        v = np.pad(v, (0, pad), constant_values=dtype(0) if op in
                   ("sum", "count") else ident)
        ids = np.pad(ids, (0, pad), constant_values=-1)

    mode = kernel_mode(interpret)
    if mode == "xla-jit":
        call = _xla_segment_call(op, np.dtype(dtype).name, n_segments)
        return np.asarray(call(jnp.asarray(v), jnp.asarray(ids)))

    vm = v.reshape(-1, _LANES)
    im = ids.reshape(-1, _LANES)
    n_seg_blocks = -(-n_segments // _LANES)
    out = np.asarray(segment_reduce_pallas(
        jnp.asarray(vm), jnp.asarray(im), n_seg_blocks, op=op,
        interpret=mode == "interpret"))
    return out[0, :n_segments]


def segment_reduce_ref(values: np.ndarray, seg_ids: np.ndarray,
                       n_segments: int, *, op: str = "sum") -> np.ndarray:
    """Pure-numpy reference (np.ufunc.at scatter)."""
    v = np.asarray(values).reshape(-1)
    ids = np.asarray(seg_ids, np.int64).reshape(-1)
    dtype = np.int32 if np.issubdtype(v.dtype, np.integer) else np.float32
    v = v.astype(dtype)
    keep = ids >= 0
    v, ids = v[keep], ids[keep]
    out = np.full((n_segments,), _identity(op, np.dtype(dtype)), dtype)
    if op == "sum":
        np.add.at(out, ids, v)
    elif op == "count":
        np.add.at(out, ids, np.ones_like(v, dtype))
    elif op == "min":
        np.minimum.at(out, ids, v)
    else:
        np.maximum.at(out, ids, v)
    return out


# ---------------------------------------------------------------------------
# windowed reductions
# ---------------------------------------------------------------------------

def _window_kernel(v_ref, out_ref, *, op: str):
    """v: (window, wb) — window axis on sublanes; out: (1, wb)."""
    v = v_ref[...]
    if op in ("sum", "count"):
        out_ref[...] = jnp.sum(v, axis=0, keepdims=True)
    elif op == "min":
        out_ref[...] = jnp.min(v, axis=0, keepdims=True)
    else:
        out_ref[...] = jnp.max(v, axis=0, keepdims=True)


@functools.lru_cache(maxsize=512)
def _window_call(w: int, nw: int, op: str, dtype_name: str,
                 interpret: bool):
    kernel = functools.partial(_window_kernel, op=op)
    call = pl.pallas_call(
        kernel,
        grid=(nw // _LANES,),
        in_specs=[pl.BlockSpec((w, _LANES), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, _LANES), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nw), np.dtype(dtype_name)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    return jax.jit(call)


def window_reduce_pallas(vt: jax.Array, *, op: str,
                         interpret: bool = False) -> jax.Array:
    """vt: (window, n_windows) with window % 8 == 0, n_windows % 128 == 0.
    Returns (1, n_windows)."""
    w, nw = vt.shape
    assert w % _SUBLANES == 0 and nw % _LANES == 0
    call = _window_call(w, nw, op, np.dtype(vt.dtype).name, interpret)
    return call(vt)


@functools.lru_cache(maxsize=512)
def _xla_window_call(op: str, dtype_name: str):
    def run(mat):                            # (n_windows, window)
        if op in ("sum", "count"):
            return jnp.sum(mat, axis=1)
        if op == "min":
            return jnp.min(mat, axis=1)
        return jnp.max(mat, axis=1)
    return jax.jit(run)


def _window_matrix(values: np.ndarray, window: int, slide: int
                   ) -> np.ndarray:
    """(n_windows, window) matrix of full windows (tail dropped)."""
    if window <= 0 or slide <= 0:
        raise ValueError("window size and slide must be positive")
    v = np.asarray(values).reshape(-1)
    if v.size < window:
        return v[:0].reshape(0, window)
    n_windows = (v.size - window) // slide + 1
    idx = (np.arange(n_windows)[:, None] * slide +
           np.arange(window)[None, :])
    return v[idx]


def window_reduce(values: np.ndarray, window: int, *, op: str = "sum",
                  slide: Optional[int] = None,
                  interpret: bool = False) -> np.ndarray:
    """Tumbling (or, with ``slide``, sliding) window reduction over a 1-D
    value sequence; only complete windows emit.  ``mean`` callers divide
    the ``sum`` result by ``window``."""
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}")
    slide = window if slide is None else slide
    mat = _window_matrix(values, window, slide)
    if mat.shape[0] == 0:
        return np.zeros((0,), np.float32)
    dtype = np.int32 if np.issubdtype(mat.dtype, np.integer) else np.float32
    mat = mat.astype(dtype)
    if op == "count":
        mat = np.ones_like(mat)
    ident = _identity(op, np.dtype(dtype))

    mode = kernel_mode(interpret)
    if mode == "xla-jit":
        call = _xla_window_call(op, np.dtype(dtype).name)
        return np.asarray(call(jnp.asarray(mat)))

    vt = np.ascontiguousarray(mat.T)          # (window, n_windows)
    w, nw = vt.shape
    pw, pn = (-w) % _SUBLANES, (-nw) % _LANES
    if pw or pn:
        fill = dtype(0) if op in ("sum", "count") else ident
        vt = np.pad(vt, ((0, pw), (0, pn)), constant_values=fill)
    out = np.asarray(window_reduce_pallas(
        jnp.asarray(vt), op=op, interpret=mode == "interpret"))
    return out[0, :nw]


def window_reduce_ref(values: np.ndarray, window: int, *, op: str = "sum",
                      slide: Optional[int] = None) -> np.ndarray:
    slide = window if slide is None else slide
    mat = _window_matrix(values, window, slide)
    dtype = np.int32 if np.issubdtype(mat.dtype, np.integer) else np.float32
    mat = mat.astype(dtype)
    if mat.shape[0] == 0:
        return np.zeros((0,), np.float32)
    fn = {"sum": np.sum, "count": np.sum, "min": np.min, "max": np.max}[op]
    if op == "count":
        mat = np.ones_like(mat)
    return fn(mat, axis=1)


# ---------------------------------------------------------------------------
# histogram (fixed uniform bins -> segmented count)
# ---------------------------------------------------------------------------

def histogram_bin_ids(values: np.ndarray, bins: int,
                      vrange: Tuple[float, float]) -> np.ndarray:
    """Uniform-bin ids with np.histogram edge semantics: values in
    [lo, hi], hi landing in the last bin; out-of-range -> -1 (dropped)."""
    lo, hi = float(vrange[0]), float(vrange[1])
    if not (bins > 0 and lo < hi):
        raise ValueError("histogram needs bins > 0 and vrange lo < hi")
    v = np.asarray(values, np.float64).reshape(-1)
    width = (hi - lo) / bins
    ids = np.floor((v - lo) / width).astype(np.int64)
    ids = np.minimum(ids, bins - 1)           # v == hi -> last bin
    ids[(v < lo) | (v > hi)] = -1
    return ids


def histogram(values: np.ndarray, bins: int, vrange: Tuple[float, float],
              *, interpret: bool = False) -> np.ndarray:
    """np.histogram-compatible uniform-bin counts via the segmented
    count kernel."""
    ids = histogram_bin_ids(values, bins, vrange)
    ones = np.ones(ids.shape, np.int32)
    return segment_reduce(ones, ids, bins, op="count", interpret=interpret)


def histogram_ref(values: np.ndarray, bins: int,
                  vrange: Tuple[float, float]) -> np.ndarray:
    return np.histogram(np.asarray(values).reshape(-1), bins=bins,
                        range=vrange)[0].astype(np.int32)


# ---------------------------------------------------------------------------
# fused filter -> segmented reduce
# ---------------------------------------------------------------------------
#
# The pushdown hot path: evaluate the shipped predicate AND fold the
# survivors into segment accumulators in one pass over the tiled block —
# no materialized boolean mask, no compacted intermediate rows.  Inputs
# arrive as individual column lanes (the colblock pruned-read shape), a
# predicate/value expression spec each, and host-computed segment ids
# for the *unfiltered* rows; rejected rows simply never match a segment
# lane.  Each call also returns per-segment survivor counts so the
# caller can drop empty groups (keeping group keys identical to the
# unfused filter-then-unique path) and derive means.

def _fused_kernel(*refs, ncols: int, order: Tuple[int, ...], rows: int,
                  op: str, ident, pred_spec: Optional[Dict],
                  value_spec: Optional[Dict], out_dtype):
    """refs: ncols column blocks (rows, 128), then ids (rows, 128), then
    acc (1, 128) and count (1, 128) outputs for this grid step's
    128-segment block."""
    col_vals = {orig: refs[j][...] for j, orig in enumerate(order)}
    id_ref, acc_ref, cnt_ref = refs[ncols], refs[ncols + 1], refs[ncols + 2]
    ids = id_ref[...]

    if pred_spec is None:
        keep = jnp.ones(ids.shape, jnp.bool_)
    else:
        keep = eval_spec(pred_spec, lambda i: col_vals[i])
        keep = jnp.broadcast_to(jnp.asarray(keep, jnp.bool_), ids.shape)
    # padding lanes carry ids == -1, so they never match a segment lane
    ids_eff = jnp.where(keep, ids, -1)

    if value_spec is None:
        val = jnp.ones(ids.shape, out_dtype)
    else:
        val = eval_spec(value_spec, lambda i: col_vals[i])
        val = jnp.broadcast_to(jnp.asarray(val).astype(out_dtype),
                               ids.shape)

    base = pl.program_id(0) * _LANES
    segs = base + jax.lax.broadcasted_iota(jnp.int32, (_LANES, _LANES), 1)

    def body(r, carry):                      # carry: ((1,128), (1,128))
        acc, cnt = carry
        mask = ids_eff[r][:, None] == segs   # (128 rows, 128 segments)
        cnt = cnt + jnp.sum(mask.astype(jnp.int32), axis=0)[None, :]
        if op == "count":
            acc = acc + jnp.sum(mask.astype(acc.dtype), axis=0)[None, :]
        elif op == "sum":
            acc = acc + jnp.sum(jnp.where(mask, val[r][:, None], 0),
                                axis=0)[None, :]
        elif op == "min":
            red = jnp.min(jnp.where(mask, val[r][:, None], ident), axis=0)
            acc = jnp.minimum(acc, red[None, :])
        else:
            red = jnp.max(jnp.where(mask, val[r][:, None], ident), axis=0)
            acc = jnp.maximum(acc, red[None, :])
        return acc, cnt

    init_acc = jnp.full_like(acc_ref, ident) if op in ("min", "max") \
        else jnp.zeros_like(acc_ref)
    acc, cnt = jax.lax.fori_loop(0, rows, body,
                                 (init_acc, jnp.zeros_like(cnt_ref)))
    acc_ref[...] = acc
    cnt_ref[...] = cnt


@functools.lru_cache(maxsize=512)
def _fused_pallas_call(rows: int, n_seg_blocks: int, op: str,
                       dtype_name: str, pred_json: str, value_json: str,
                       order: Tuple[int, ...], interpret: bool):
    dtype = np.dtype(dtype_name)
    ncols = len(order)
    kernel = functools.partial(
        _fused_kernel, ncols=ncols, order=order, rows=rows, op=op,
        ident=_identity(op, dtype),
        pred_spec=json.loads(pred_json) if pred_json else None,
        value_spec=json.loads(value_json) if value_json else None,
        out_dtype=dtype)
    call = pl.pallas_call(
        kernel,
        grid=(n_seg_blocks,),
        in_specs=[pl.BlockSpec((rows, _LANES), lambda i: (0, 0))
                  for _ in range(ncols + 1)],
        out_specs=[pl.BlockSpec((1, _LANES), lambda i: (0, i)),
                   pl.BlockSpec((1, _LANES), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, n_seg_blocks * _LANES), dtype),
                   jax.ShapeDtypeStruct((1, n_seg_blocks * _LANES),
                                        np.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    return jax.jit(call)


_XLA_FOLD_SEGMENTS = 64            # membership-fold beats scatter below this
_XLA_FOLD_CHUNK = 1 << 13          # rows per scan step (fits L2 with mask)


@functools.lru_cache(maxsize=512)
def _fused_xla_call(op: str, dtype_name: str, n_segments: int,
                    pred_json: str, value_json: str,
                    order: Tuple[int, ...]):
    """Compiled XLA fusion for the non-TPU path: predicate + value +
    segmented reduce in one jitted program.  Small segment counts run
    the same membership fold the Pallas kernel uses — a streaming
    chunked pass carrying one accumulator lane per segment, no scatter
    and no materialised mask; larger counts fall back to XLA's segment
    scatter with a dump bucket for rejected/padding rows."""
    pred_spec = json.loads(pred_json) if pred_json else None
    value_spec = json.loads(value_json) if value_json else None
    dtype = np.dtype(dtype_name)
    ident = _identity(op, dtype)

    def _eval(ids, colarrs):
        cols = {orig: colarrs[j] for j, orig in enumerate(order)}
        if pred_spec is None:
            keep = ids >= 0
        else:
            keep = eval_spec(pred_spec, lambda i: cols[i])
            keep = jnp.broadcast_to(jnp.asarray(keep, jnp.bool_),
                                    ids.shape) & (ids >= 0)
        if value_spec is None:
            val = jnp.ones(ids.shape, dtype)
        else:
            val = eval_spec(value_spec, lambda i: cols[i])
            val = jnp.broadcast_to(jnp.asarray(val).astype(dtype),
                                   ids.shape)
        return keep, val

    def _fold(ids, colarrs, acc, cnt):
        keep, val = _eval(ids, colarrs)
        ids_eff = jnp.where(keep, ids, -1)
        m = ids_eff[:, None] == jnp.arange(n_segments,
                                           dtype=jnp.int32)[None, :]
        mv = jnp.where(m, val[:, None], jnp.asarray(ident, dtype))
        if op in ("sum", "count"):
            acc = acc + jnp.sum(mv, axis=0)
        elif op == "min":
            acc = jnp.minimum(acc, jnp.min(mv, axis=0))
        else:
            acc = jnp.maximum(acc, jnp.max(mv, axis=0))
        return acc, cnt + jnp.sum(m, axis=0, dtype=jnp.int32)

    def run(ids, *colarrs):
        if n_segments <= _XLA_FOLD_SEGMENTS:
            n, ch = ids.shape[0], _XLA_FOLD_CHUNK
            acc = jnp.full((n_segments,), ident, dtype)
            cnt = jnp.zeros((n_segments,), jnp.int32)
            main = (n // ch) * ch
            if main:
                def body(carry, xs):
                    return _fold(xs[0], xs[1:], *carry), None
                xs = (ids[:main].reshape(-1, ch),) + tuple(
                    c[:main].reshape(-1, ch) for c in colarrs)
                (acc, cnt), _ = jax.lax.scan(body, (acc, cnt), xs)
            if n > main:
                acc, cnt = _fold(ids[main:],
                                 [c[main:] for c in colarrs], acc, cnt)
            return acc, cnt
        keep, val = _eval(ids, colarrs)
        idx = jnp.where(keep, ids, n_segments)
        seg = {"sum": jax.ops.segment_sum, "count": jax.ops.segment_sum,
               "min": jax.ops.segment_min, "max": jax.ops.segment_max}[op]
        acc = seg(val, idx, num_segments=n_segments + 1)
        cnt = jax.ops.segment_sum(keep.astype(jnp.int32), idx,
                                  num_segments=n_segments + 1)
        return acc[:n_segments], cnt[:n_segments]
    return jax.jit(run)


def fused_out_dtype(value_spec: Optional[Dict],
                    coldt: Dict[int, np.dtype]) -> np.dtype:
    """int32/float32 accumulator choice, identical to what the unfused
    path gets from evaluating the value expr on numpy rows."""
    if value_spec is None:
        return np.dtype(np.int32)            # count's ones
    dt = _spec_dtype(value_spec, coldt)
    return np.dtype(np.int32) if np.issubdtype(dt, np.integer) \
        else np.dtype(np.float32)


def fused_filter_aggregate(cols: Dict[int, np.ndarray],
                           pred_spec: Optional[Dict],
                           value_spec: Optional[Dict],
                           seg_ids: np.ndarray, n_segments: int, *,
                           op: str, interpret: bool = False,
                           out_dtype=None
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """One-pass filter -> segmented reduce over column arrays.

    ``cols`` maps original column index -> (rows,) array (a pruned
    colblock read or sliced row-major block); ``seg_ids`` are
    host-computed int32 ids in [0, n_segments) over the *unfiltered*
    rows (-1 drops a row unconditionally).  Returns
    ``(agg, counts)`` of shape (n_segments,): the op-reduced survivor
    values (op identity where no survivors) and survivor counts.
    Integer aggregates are exact int32 — bit-identical to the unfused
    mask-then-reduce path on every backend.  ``out_dtype`` overrides the
    inferred int32/float32 accumulator (grouped means reduce integer
    values in float32, matching the unfused cast-then-reduce).
    """
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}")
    ids = np.asarray(seg_ids, np.int32).reshape(-1)
    n = ids.size
    order = tuple(sorted(cols))
    coldt = {i: np.asarray(cols[i]).dtype for i in order}
    dtype = np.dtype(out_dtype) if out_dtype is not None \
        else fused_out_dtype(value_spec, coldt)
    ident = _identity(op, dtype)
    if n_segments <= 0 or n == 0:
        return (np.full((max(n_segments, 0),), ident, dtype),
                np.zeros((max(n_segments, 0),), np.int32))

    pred_json = json.dumps(pred_spec, sort_keys=True) if pred_spec else ""
    value_json = json.dumps(value_spec, sort_keys=True) if value_spec \
        else ""

    pad = (-n) % _TILE
    ids_p = np.pad(ids, (0, pad), constant_values=-1) if pad else ids
    col_p = []
    for i in order:
        c = np.asarray(cols[i]).reshape(-1)
        if c.size != n:
            raise ValueError(f"column {i} has {c.size} rows, ids {n}")
        # pad value 1 keeps pad-lane predicate math away from div-by-zero
        col_p.append(np.pad(c, (0, pad), constant_values=c.dtype.type(1))
                     if pad else c)

    mode = kernel_mode(interpret)
    if mode == "xla-jit":
        call = _fused_xla_call(op, dtype.name, n_segments, pred_json,
                               value_json, order)
        acc, cnt = call(jnp.asarray(ids_p),
                        *[jnp.asarray(c) for c in col_p])
        return np.asarray(acc), np.asarray(cnt)

    rows = ids_p.size // _LANES
    n_seg_blocks = -(-n_segments // _LANES)
    call = _fused_pallas_call(rows, n_seg_blocks, op, dtype.name,
                              pred_json, value_json, order,
                              mode == "interpret")
    acc, cnt = call(*[jnp.asarray(c.reshape(-1, _LANES)) for c in col_p],
                    jnp.asarray(ids_p.reshape(-1, _LANES)))
    return (np.asarray(acc)[0, :n_segments],
            np.asarray(cnt)[0, :n_segments])


def fused_filter_aggregate_ref(cols: Dict[int, np.ndarray],
                               pred_spec: Optional[Dict],
                               value_spec: Optional[Dict],
                               seg_ids: np.ndarray, n_segments: int, *,
                               op: str) -> Tuple[np.ndarray, np.ndarray]:
    """Pure-numpy reference: materialize the mask, compact, reduce —
    exactly the unfused path the fused kernel must match."""
    ids = np.asarray(seg_ids, np.int64).reshape(-1)
    order = tuple(sorted(cols))
    coldt = {i: np.asarray(cols[i]).dtype for i in order}
    dtype = fused_out_dtype(value_spec, coldt)
    getcol = lambda i: np.asarray(cols[i]).reshape(-1)   # noqa: E731
    if pred_spec is None:
        keep = ids >= 0
    else:
        keep = np.broadcast_to(
            np.asarray(eval_spec(pred_spec, getcol), bool),
            ids.shape) & (ids >= 0)
    if value_spec is None:
        val = np.ones(ids.shape, dtype)
    else:
        val = np.broadcast_to(
            np.asarray(eval_spec(value_spec, getcol)).astype(dtype),
            ids.shape)
    ids_k, val_k = ids[keep], val[keep]
    acc = segment_reduce_ref(val_k.astype(dtype), ids_k, n_segments, op=op)
    cnt = segment_reduce_ref(np.ones(ids_k.shape, np.int32), ids_k,
                             n_segments, op="count")
    return acc.astype(dtype), cnt


# ---------------------------------------------------------------------------
# kernel-closure cache introspection
# ---------------------------------------------------------------------------

_CACHED_BUILDERS = (_segment_call, _xla_segment_call, _window_call,
                    _xla_window_call, _fused_pallas_call, _fused_xla_call)


def kernel_cache_info() -> Dict[str, int]:
    """Aggregate hit/miss/entry counts over every cached jitted-kernel
    builder — a miss is one trace+compile; hits reuse the closure."""
    hits = misses = entries = 0
    for b in _CACHED_BUILDERS:
        ci = b.cache_info()
        hits, misses, entries = (hits + ci.hits, misses + ci.misses,
                                 entries + ci.currsize)
    return {"hits": hits, "misses": misses, "entries": entries}


def kernel_cache_clear():
    for b in _CACHED_BUILDERS:
        b.cache_clear()
